//! Admission queue + continuous-batching bookkeeping.

use std::collections::VecDeque;
use std::time::Instant;

use super::request::{FinishReason, Request, RequestId, Response};

/// A request currently holding a KV slot.
#[derive(Debug)]
pub struct Running {
    pub request: Request,
    pub slot: usize,
    pub generated: Vec<i32>,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Instant,
    pub tpot: Vec<f64>,
}

impl Running {
    pub fn new(request: Request, slot: usize) -> Self {
        Self {
            request,
            slot,
            generated: Vec::new(),
            first_token_at: None,
            last_token_at: Instant::now(),
            tpot: Vec::new(),
        }
    }

    pub fn push_token(&mut self, tok: i32) {
        let now = Instant::now();
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        } else {
            self.tpot.push(now.duration_since(self.last_token_at).as_secs_f64());
        }
        self.last_token_at = now;
        self.generated.push(tok);
    }

    pub fn should_stop(&self, remaining_cache: usize) -> Option<FinishReason> {
        if let (Some(stop), Some(&last)) =
            (self.request.stop_token, self.generated.last())
        {
            if last == stop {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.request.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if remaining_cache == 0 {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    /// Finalize with the real finish reason (from `should_stop`, or
    /// `Cancelled` on shutdown).
    pub fn into_response(self, finished: FinishReason) -> Response {
        let ttft = self
            .first_token_at
            .map(|t| t.duration_since(self.request.submitted).as_secs_f64())
            .unwrap_or(0.0);
        Response {
            id: self.request.id,
            tokens: self.generated,
            ttft,
            tpot: self.tpot,
            finished,
            echo_text: self.request.echo_text,
        }
    }
}

/// FIFO waiting queue.
#[derive(Debug, Default)]
pub struct Batcher {
    waiting: VecDeque<Request>,
    next_id: RequestId,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        self.next_id += 1;
        let id = self.next_id;
        self.waiting.push_back(Request::new(id, prompt, max_new));
        id
    }

    pub fn submit_request(&mut self, r: Request) {
        self.next_id = self.next_id.max(r.id);
        self.waiting.push_back(r);
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.waiting.pop_front()
    }

    /// Return a popped request to the head of the queue (admission saw
    /// it but has no free slot yet; FIFO order is preserved).
    pub fn push_front(&mut self, r: Request) {
        self.waiting.push_front(r);
    }

    /// Remove a still-queued request (client disconnected before its
    /// prefill was admitted).
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        let pos = self.waiting.iter().position(|r| r.id == id)?;
        self.waiting.remove(pos)
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new();
        let a = b.submit(vec![1], 4);
        let c = b.submit(vec![2], 4);
        assert!(a < c);
        assert_eq!(b.pop().unwrap().id, a);
        assert_eq!(b.pop().unwrap().id, c);
        assert!(b.pop().is_none());
    }

    #[test]
    fn remove_plucks_from_queue() {
        let mut b = Batcher::new();
        let a = b.submit(vec![1], 4);
        let c = b.submit(vec![2], 4);
        let d = b.submit(vec![3], 4);
        assert_eq!(b.remove(c).unwrap().id, c);
        assert!(b.remove(c).is_none());
        assert_eq!(b.waiting(), 2);
        assert_eq!(b.pop().unwrap().id, a);
        assert_eq!(b.pop().unwrap().id, d);
    }

    #[test]
    fn stop_conditions() {
        let mut r = Running::new(Request::new(1, vec![0], 2), 0);
        assert!(r.should_stop(10).is_none());
        r.push_token(5);
        assert!(r.should_stop(10).is_none());
        r.push_token(6);
        assert_eq!(r.should_stop(10), Some(FinishReason::MaxTokens));

        let mut r = Running::new(Request::new(2, vec![0], 50), 0);
        r.push_token(crate::data::NL);
        assert_eq!(r.should_stop(10), Some(FinishReason::StopToken));

        let mut r = Running::new(Request::new(3, vec![0], 50), 0);
        r.push_token(7);
        assert_eq!(r.should_stop(0), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn timing_accumulates() {
        let mut r = Running::new(Request::new(1, vec![0], 8), 0);
        r.push_token(1);
        r.push_token(2);
        r.push_token(3);
        assert_eq!(r.tpot.len(), 2); // first token counts toward TTFT
        let resp = r.into_response(FinishReason::StopToken);
        assert_eq!(resp.tokens, vec![1, 2, 3]);
        assert_eq!(resp.finished, FinishReason::StopToken);
        assert!(resp.ttft >= 0.0);
    }
}
