//! The continuous-batching step loop (vLLM-style): each step admits at
//! most one waiting prefill into a free slot (prefill-priority keeps
//! TTFT low), then runs one batched decode step over every running slot.

use std::collections::HashMap;

use crate::data::PAD;

use super::batcher::{Batcher, Running};
use super::engine::Engine;
use super::metrics::Metrics;
use super::request::{FinishReason, Request, RequestId, Response};

pub struct Scheduler {
    pub engine: Engine,
    pub batcher: Batcher,
    pub metrics: Metrics,
    running: HashMap<usize, Running>, // slot -> running request
    finished: Vec<Response>,
}

impl Scheduler {
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            batcher: Batcher::new(),
            metrics: Metrics::new(),
            running: HashMap::new(),
            finished: Vec::new(),
        }
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        self.batcher.submit(prompt, max_new)
    }

    pub fn submit_request(&mut self, r: Request) {
        self.batcher.submit_request(r);
    }

    pub fn has_work(&self) -> bool {
        self.batcher.waiting() > 0 || !self.running.is_empty()
    }

    /// One scheduler step. Returns the number of tokens produced.
    pub fn step(&mut self) -> crate::Result<usize> {
        let mut produced = 0;

        // 1) admit one prefill if a slot is free
        if self.engine.kv.free_count() > 0 {
            if let Some(req) = self.batcher.pop() {
                let slot = self
                    .engine
                    .kv
                    .alloc(req.id, req.prompt.len())
                    .ok_or_else(|| anyhow::anyhow!("prompt does not fit cache"))?;
                let t0 = std::time::Instant::now();
                let first = self.engine.prefill(slot, &req.prompt)?;
                self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                let mut running = Running::new(req, slot);
                // NOTE: `first` is generated but its KV is not cached yet;
                // kv.tok_len stays at prompt_len until the decode step that
                // feeds it (the cache invariant: tok_len == cached tokens).
                running.push_token(first);
                produced += 1;
                self.maybe_finish(slot, running);
            }
        }

        // 2) batched decode over all running slots
        if !self.running.is_empty() {
            let b = self.engine.kv.n_slots;
            let mut tokens = vec![PAD; b];
            for (&slot, run) in &self.running {
                tokens[slot] = *run.generated.last().unwrap();
            }
            let t0 = std::time::Instant::now();
            let next = self.engine.decode_step(&tokens)?;
            let dt = t0.elapsed().as_secs_f64();
            self.metrics.record_decode(dt, self.running.len());

            let slots: Vec<usize> = self.running.keys().copied().collect();
            for slot in slots {
                let mut run = self.running.remove(&slot).unwrap();
                // the token we just fed is now cached at position tok_len
                self.engine.kv.push_token(slot);
                run.push_token(next[slot]);
                produced += 1;
                self.maybe_finish(slot, run);
            }
        }
        Ok(produced)
    }

    fn maybe_finish(&mut self, slot: usize, run: Running) {
        match run.should_stop(self.engine.kv.remaining(slot)) {
            Some(reason) => {
                self.engine.kv.free(slot);
                let resp = run.into_response(reason);
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            }
            None => {
                self.running.insert(slot, run);
            }
        }
    }

    /// Run until the queue and all slots drain; returns all responses.
    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Cancel everything in flight (server shutdown).
    pub fn cancel_all(&mut self) {
        let slots: Vec<usize> = self.running.keys().copied().collect();
        for slot in slots {
            let run = self.running.remove(&slot).unwrap();
            self.engine.kv.free(slot);
            self.finished.push(run.into_response(FinishReason::Cancelled));
        }
    }
}
