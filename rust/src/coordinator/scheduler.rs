//! The continuous-batching step loop (vLLM-style): each step admits
//! waiting prefills into *every* free slot (prefill-priority keeps TTFT
//! low), then runs one batched decode step over every running slot.
//!
//! Fault isolation: `step()` returning `Err` means the *engine* failed
//! (a batched decode aborted — systemic, affects every slot). Anything
//! wrong with a single request — an oversized or empty prompt, an
//! out-of-vocab token, a prefill that rejects its input — is converted
//! at admission into a `FinishReason::Error` response in `finished` and
//! the loop keeps serving everyone else.

use std::collections::HashMap;

use crate::data::PAD;

use super::batcher::{Batcher, Running};
use super::engine::Engine;
use super::metrics::Metrics;
use super::request::{FinishReason, Request, RequestId, Response};

pub struct Scheduler {
    pub engine: Engine,
    pub batcher: Batcher,
    pub metrics: Metrics,
    running: HashMap<usize, Running>, // slot -> running request
    finished: Vec<Response>,
    /// (request, token) pairs in generation order since the last
    /// `take_token_events` — the streaming front end drains these to
    /// emit one wire line per generated token. Only requests submitted
    /// with `stream: true` record events, so offline consumers that
    /// never drain (benches, run_to_completion) accumulate nothing.
    token_events: Vec<(RequestId, i32)>,
}

impl Scheduler {
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            batcher: Batcher::new(),
            metrics: Metrics::new(),
            running: HashMap::new(),
            finished: Vec::new(),
            token_events: Vec::new(),
        }
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        self.batcher.submit(prompt, max_new)
    }

    pub fn submit_request(&mut self, r: Request) {
        self.batcher.submit_request(r);
    }

    pub fn has_work(&self) -> bool {
        self.batcher.waiting() > 0 || !self.running.is_empty()
    }

    /// Why `req` can never be served, if so: checked before a KV slot is
    /// committed. `None` means the request is admissible (and with a
    /// free slot, `kv.alloc` cannot fail).
    pub fn admission_error(&self, req: &Request) -> Option<String> {
        let m = &self.engine.session.manifest;
        let kv = &self.engine.kv;
        if req.prompt.is_empty() {
            return Some("empty prompt".to_string());
        }
        if req.prompt.len() > m.seq_len {
            return Some(format!(
                "prompt too long: {} tokens exceeds the prefill window {}",
                req.prompt.len(),
                m.seq_len
            ));
        }
        if kv.m_max + req.prompt.len() > kv.cap {
            return Some(format!(
                "prompt does not fit a kv slot: {} prefix + {} prompt > cap {}",
                kv.m_max,
                req.prompt.len(),
                kv.cap
            ));
        }
        if let Some(&t) = req
            .prompt
            .iter()
            .find(|&&t| t < 0 || t as usize >= m.vocab)
        {
            return Some(format!("token {t} outside vocab [0, {})", m.vocab));
        }
        None
    }

    /// Finish `req` with a per-request error response (never an engine
    /// error): the "one bad request crashes the fleet" class dies here.
    fn reject(&mut self, req: Request, why: String) {
        log::debug!("request {} rejected: {why}", req.id);
        let resp = Response::rejection(req.id, req.echo_text, why);
        self.metrics.record_finished(&resp);
        self.finished.push(resp);
    }

    /// One scheduler step. Returns the number of tokens produced.
    /// `Err` is reserved for engine-level (batch-wide) failures.
    pub fn step(&mut self) -> crate::Result<usize> {
        let mut produced = 0;

        // 1) admit waiting prefills into every free slot. Inadmissible
        //    requests are rejected even when no slot is free — a poisoned
        //    queue must drain instead of festering behind long runners.
        loop {
            let Some(req) = self.batcher.pop() else { break };
            if let Some(why) = self.admission_error(&req) {
                self.reject(req, why);
                continue;
            }
            if self.engine.kv.free_count() == 0 {
                self.batcher.push_front(req);
                break;
            }
            let Some(slot) = self.engine.kv.alloc(req.id, req.prompt.len()) else {
                // unreachable after admission_error + free_count guard,
                // but a rejection is still strictly better than a crash
                self.reject(req, "no free kv slot".to_string());
                continue;
            };
            let t0 = std::time::Instant::now();
            match self.engine.prefill(slot, &req.prompt) {
                Ok(first) => {
                    self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                    let mut running = Running::new(req, slot);
                    // NOTE: `first` is generated but its KV is not cached
                    // yet; kv.tok_len stays at prompt_len until the decode
                    // step that feeds it (the cache invariant: tok_len ==
                    // cached tokens).
                    running.push_token(first);
                    if running.request.stream {
                        self.token_events.push((running.request.id, first));
                    }
                    produced += 1;
                    self.maybe_finish(slot, running);
                }
                Err(e) => {
                    // prefill consumes only this request's input, so its
                    // failure is request-scoped: free the slot, error the
                    // request, keep the engine alive.
                    self.engine.kv.free(slot);
                    self.reject(req, format!("prefill failed: {e:#}"));
                }
            }
        }

        // 2) batched decode over all running slots
        if !self.running.is_empty() {
            let b = self.engine.kv.n_slots;
            let mut tokens = vec![PAD; b];
            for (&slot, run) in &self.running {
                tokens[slot] = *run.generated.last().unwrap();
            }
            let t0 = std::time::Instant::now();
            // meter the step's host-boundary traffic alongside its
            // latency: the bytes-per-step gauges in the serve metrics
            let (next, xfer) =
                crate::runtime::transfer::measure(|| self.engine.decode_step(&tokens));
            let next = next?;
            let dt = t0.elapsed().as_secs_f64();
            self.metrics.record_decode(dt, self.running.len(), xfer);

            let slots: Vec<usize> = self.running.keys().copied().collect();
            for slot in slots {
                let mut run = self.running.remove(&slot).unwrap();
                // the token we just fed is now cached at position tok_len
                self.engine.kv.push_token(slot);
                run.push_token(next[slot]);
                if run.request.stream {
                    self.token_events.push((run.request.id, next[slot]));
                }
                produced += 1;
                self.maybe_finish(slot, run);
            }
        }
        Ok(produced)
    }

    fn maybe_finish(&mut self, slot: usize, run: Running) {
        match run.should_stop(self.engine.kv.remaining(slot)) {
            Some(reason) => {
                self.engine.kv.free(slot);
                let resp = run.into_response(reason);
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            }
            None => {
                self.running.insert(slot, run);
            }
        }
    }

    /// Run until the queue and all slots drain; returns all responses.
    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Drain the per-token stream events accumulated since the last call.
    pub fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        std::mem::take(&mut self.token_events)
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Cancel one request (client disconnect): drops it from the waiting
    /// queue, or frees its KV slot if already running. Returns true if
    /// the request was found in either place.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(req) = self.batcher.remove(id) {
            self.metrics.record_cancelled();
            self.finished.push(Response::cancelled(req.id, req.echo_text));
            return true;
        }
        let slot = self
            .running
            .iter()
            .find(|(_, run)| run.request.id == id)
            .map(|(&slot, _)| slot);
        match slot {
            Some(slot) => {
                let run = self.running.remove(&slot).unwrap();
                self.engine.kv.free(slot);
                self.metrics.record_cancelled();
                self.finished.push(run.into_response(FinishReason::Cancelled));
                true
            }
            None => false,
        }
    }

    /// Cancel everything in flight (server shutdown).
    pub fn cancel_all(&mut self) {
        let slots: Vec<usize> = self.running.keys().copied().collect();
        for slot in slots {
            let run = self.running.remove(&slot).unwrap();
            self.engine.kv.free(slot);
            self.metrics.record_cancelled();
            self.finished.push(run.into_response(FinishReason::Cancelled));
        }
        while let Some(req) = self.batcher.pop() {
            self.metrics.record_cancelled();
            self.finished.push(Response::cancelled(req.id, req.echo_text));
        }
    }
}
