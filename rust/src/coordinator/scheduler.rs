//! The continuous-batching step loop (vLLM-style), paged edition: each
//! step admits work while a lane *and enough KV blocks* are available
//! (prefill-priority keeps TTFT low), grows running sequences' block
//! tables for the step's decode writes — preempting the youngest
//! running sequence back to the queue when the pool runs dry — then
//! runs one batched decode step over every running slot.
//!
//! Preemption frees the victim's non-shared blocks (full prompt blocks
//! are donated to the prefix cache first, so the resume re-prefill can
//! share them back) and requeues the sequence with everything it has
//! generated; resuming re-prefills `prompt ++ generated`, whose
//! last-position logits are exactly the decode step the preemption
//! interrupted — in fp and statically-quantized modes the resumed token
//! stream is bit-identical to an uninterrupted run (per-row arithmetic
//! is batch-independent; the paged_kv preemption test pins this). In
//! *dynamic* per-tensor modes (ptd/ptk) the re-prefill's dynamic ranges
//! span a different activation batch, so resumed tokens may round
//! differently — same model, same semantics, different batch shape.
//! Starvation-freedom: victims are always strictly younger than the
//! oldest running sequence (which therefore completes), and the batcher
//! admits by submission age across fresh and preempted work.
//!
//! Fault isolation: `step()` returning `Err` means the *engine* failed
//! (a batched decode aborted — systemic, affects every slot). Anything
//! wrong with a single request — an oversized or empty prompt, an
//! out-of-vocab token, a prefill that rejects its input — is converted
//! at admission into a `FinishReason::Error` response in `finished` and
//! the loop keeps serving everyone else.

use std::collections::HashMap;

use crate::data::PAD;

use super::batcher::{Admit, Batcher, Running};
use super::engine::Engine;
use super::metrics::Metrics;
use super::request::{FinishReason, Request, RequestId, Response};

pub struct Scheduler {
    pub engine: Engine,
    pub batcher: Batcher,
    pub metrics: Metrics,
    running: HashMap<usize, Running>, // slot -> running request
    finished: Vec<Response>,
    /// (request, token) pairs in generation order since the last
    /// `take_token_events` — the streaming front end drains these to
    /// emit one wire line per generated token. Only requests submitted
    /// with `stream: true` record events, so offline consumers that
    /// never drain (benches, run_to_completion) accumulate nothing.
    token_events: Vec<(RequestId, i32)>,
}

impl Scheduler {
    pub fn new(engine: Engine) -> Self {
        let mut metrics = Metrics::new();
        metrics.pool_blocks_total = engine.kv.total_blocks();
        Self {
            engine,
            batcher: Batcher::new(),
            metrics,
            running: HashMap::new(),
            finished: Vec::new(),
            token_events: Vec::new(),
        }
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        self.batcher.submit(prompt, max_new)
    }

    pub fn submit_request(&mut self, r: Request) {
        self.batcher.submit_request(r);
    }

    pub fn has_work(&self) -> bool {
        self.batcher.waiting() > 0 || !self.running.is_empty()
    }

    /// Why `req` can never be served, if so: checked before a KV lane is
    /// committed. `None` means the request is admissible — though it may
    /// still have to *wait* for lanes or blocks. A prompt of exactly
    /// `cap - m_max` tokens is admissible with zero decode room: it is
    /// served its prefill token and finished with `Length` immediately
    /// (the pre-paging `alloc` admitted it and relied on overflow
    /// asserts downstream).
    pub fn admission_error(&self, req: &Request) -> Option<String> {
        let m = &self.engine.session.manifest;
        let kv = &self.engine.kv;
        if req.prompt.is_empty() {
            return Some("empty prompt".to_string());
        }
        if req.prompt.len() > m.seq_len {
            return Some(format!(
                "prompt too long: {} tokens exceeds the prefill window {}",
                req.prompt.len(),
                m.seq_len
            ));
        }
        if kv.m_max + req.prompt.len() > kv.cap {
            return Some(format!(
                "prompt does not fit the kv space: {} prefix + {} prompt > cap {}",
                kv.m_max,
                req.prompt.len(),
                kv.cap
            ));
        }
        if let Some(&t) = req
            .prompt
            .iter()
            .find(|&&t| t < 0 || t as usize >= m.vocab)
        {
            return Some(format!("token {t} outside vocab [0, {})", m.vocab));
        }
        None
    }

    /// Finish `req` with a per-request error response (never an engine
    /// error): the "one bad request crashes the fleet" class dies here.
    fn reject(&mut self, req: Request, why: String) {
        log::debug!("request {} rejected: {why}", req.id);
        let resp = Response::rejection(req.id, req.echo_text, why);
        self.metrics.record_finished(&resp);
        self.finished.push(resp);
    }

    /// One scheduler step. Returns the number of tokens produced.
    /// `Err` is reserved for engine-level (batch-wide) failures.
    pub fn step(&mut self) -> crate::Result<usize> {
        let mut produced = 0;

        // 1) admission, oldest-submission first across fresh requests
        //    and preempted resumes. Inadmissible requests are rejected
        //    even when nothing can be admitted — a poisoned queue must
        //    drain instead of festering behind long runners. Admission
        //    stops when a lane or the block pool says "wait".
        loop {
            let Some(next) = self.batcher.pop_next() else { break };
            match next {
                Admit::New(req) => {
                    if let Some(why) = self.admission_error(&req) {
                        self.reject(req, why);
                        continue;
                    }
                    if self.engine.kv.free_count() == 0
                        || !self.engine.kv.can_admit(&req.prompt, req.max_new_tokens)
                    {
                        self.batcher.push_front(req);
                        break;
                    }
                    let Some(slot) =
                        self.engine.kv.alloc_with_prompt(req.id, &req.prompt)
                    else {
                        // the pool moved between can_admit and alloc
                        // (conservative math) — wait, don't reject
                        self.batcher.push_front(req);
                        break;
                    };
                    produced += self.admit_prefill(slot, Running::new(req, slot));
                }
                Admit::Resume(run) => {
                    let tokens = run.resume_tokens();
                    // the real remaining budget: a resume one token shy
                    // of max_new needs no decode room beyond its prefill
                    let budget = run
                        .request
                        .max_new_tokens
                        .saturating_sub(run.generated.len())
                        .max(1);
                    if self.engine.kv.free_count() == 0
                        || !self.engine.kv.can_admit(&tokens, budget)
                    {
                        self.batcher.push_resume(run);
                        break;
                    }
                    let Some(slot) = self
                        .engine
                        .kv
                        .alloc_with_prompt(run.request.id, &tokens)
                    else {
                        self.batcher.push_resume(run);
                        break;
                    };
                    produced += self.resume_prefill(slot, run, &tokens);
                }
            }
        }

        // 2) every running sequence must be able to cache the token this
        //    step feeds it; preempt the youngest when the pool is dry
        self.ensure_decode_room();

        // 3) batched decode over all running slots
        if !self.running.is_empty() {
            let b = self.engine.kv.n_slots;
            let mut tokens = vec![PAD; b];
            for (&slot, run) in &self.running {
                tokens[slot] = *run.generated.last().unwrap();
            }
            let t0 = std::time::Instant::now();
            // meter the step's host-boundary traffic alongside its
            // latency: the bytes-per-step gauges in the serve metrics
            let (next, xfer) =
                crate::runtime::transfer::measure(|| self.engine.decode_step(&tokens));
            let next = next?;
            let dt = t0.elapsed().as_secs_f64();
            self.metrics.record_decode(dt, self.running.len(), xfer);

            let slots: Vec<usize> = self.running.keys().copied().collect();
            for slot in slots {
                let mut run = self.running.remove(&slot).unwrap();
                // the token we just fed is now cached at position tok_len
                self.engine.kv.push_token(slot);
                run.push_token(next[slot]);
                if run.request.stream {
                    self.token_events.push((run.request.id, next[slot]));
                }
                produced += 1;
                self.maybe_finish(slot, run);
            }
        }
        self.metrics.record_pool(self.engine.kv.pool_stats());
        Ok(produced)
    }

    /// Prefill a freshly admitted request; returns produced tokens (1 on
    /// success).
    fn admit_prefill(&mut self, slot: usize, mut running: Running) -> usize {
        let t0 = std::time::Instant::now();
        match self.engine.prefill(slot, &running.request.prompt) {
            Ok(first) => {
                self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                // NOTE: `first` is generated but its KV is not cached
                // yet; kv.tok_len stays at prompt_len until the decode
                // step that feeds it (the cache invariant: tok_len ==
                // cached tokens).
                running.push_token(first);
                if running.request.stream {
                    self.token_events.push((running.request.id, first));
                }
                self.maybe_finish(slot, running);
                1
            }
            Err(e) => {
                // prefill consumes only this request's input, so its
                // failure is request-scoped: free the lane, error the
                // request, keep the engine alive.
                self.engine.kv.free(slot);
                self.reject(running.request, format!("prefill failed: {e:#}"));
                0
            }
        }
    }

    /// Re-prefill a preempted sequence (`prompt ++ generated`) and
    /// continue it; returns produced tokens (1 on success).
    fn resume_prefill(&mut self, slot: usize, mut run: Running, tokens: &[i32]) -> usize {
        let t0 = std::time::Instant::now();
        match self.engine.prefill(slot, tokens) {
            Ok(next) => {
                self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                run.slot = slot;
                run.push_token(next);
                if run.request.stream {
                    self.token_events.push((run.request.id, next));
                }
                self.maybe_finish(slot, run);
                1
            }
            Err(e) => {
                self.engine.kv.free(slot);
                let id = run.request.id;
                log::debug!("resume of request {id} failed: {e:#}");
                let resp = run
                    .into_response(FinishReason::Error(format!("resume failed: {e:#}")));
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
                0
            }
        }
    }

    /// Guarantee each running sequence a block for this step's KV write,
    /// preempting the youngest running sequence (never the oldest — the
    /// anti-starvation invariant) while the pool is dry. When no other
    /// victim exists the starved sequence preempts *itself* (waiting in
    /// the resume queue costs latency, not tokens; progress is
    /// guaranteed because a lone resume always fits the pool floor and
    /// each resume cycle generates at least its prefill token). Only a
    /// sequence that can never be resumed — its re-prefill would exceed
    /// the prefill window — is finished early with `Length`.
    fn ensure_decode_room(&mut self) {
        let seq_len = self.engine.session.manifest.seq_len;
        let mut slots: Vec<usize> = self.running.keys().copied().collect();
        slots.sort_unstable();
        for slot in slots {
            if !self.running.contains_key(&slot) {
                continue; // preempted while making room for an earlier slot
            }
            loop {
                if self.engine.kv.ensure_append(slot) {
                    break;
                }
                match self.pick_victim() {
                    Some(victim) => {
                        let preempted_self = victim == slot;
                        self.preempt(victim);
                        if preempted_self {
                            break;
                        }
                    }
                    None => {
                        let run = &self.running[&slot];
                        let resumable = run.request.prompt.len()
                            + run.generated.len()
                            <= seq_len;
                        if resumable {
                            self.preempt(slot);
                        } else {
                            // unresumable and the pool cannot grow it:
                            // the only honest terminal is truncation
                            let run = self.running.remove(&slot).unwrap();
                            self.engine.kv.free(slot);
                            let resp = run.into_response(FinishReason::Length);
                            self.metrics.record_finished(&resp);
                            self.finished.push(resp);
                        }
                        break;
                    }
                }
            }
        }
    }

    /// The preemption victim: the youngest-submitted running sequence
    /// that can be resumed later (its re-prefill must fit the prefill
    /// window), excluding the oldest running sequence.
    fn pick_victim(&self) -> Option<usize> {
        if self.running.len() < 2 {
            return None;
        }
        let seq_len = self.engine.session.manifest.seq_len;
        let oldest = self
            .running
            .iter()
            .min_by_key(|(_, r)| (r.request.submitted, r.request.id))
            .map(|(&s, _)| s)?;
        self.running
            .iter()
            .filter(|&(&s, r)| {
                s != oldest
                    && r.request.prompt.len() + r.generated.len() <= seq_len
            })
            .max_by_key(|(_, r)| (r.request.submitted, r.request.id))
            .map(|(&s, _)| s)
    }

    /// Move a running sequence back to the queue: free its lane and
    /// non-shared blocks (full prompt blocks are donated to the prefix
    /// cache on the way out) and let it resume by re-prefill.
    fn preempt(&mut self, slot: usize) {
        let run = self.running.remove(&slot).unwrap();
        log::debug!(
            "preempting request {} ({} generated) — kv pool dry",
            run.request.id,
            run.generated.len()
        );
        self.engine.kv.free(slot);
        self.metrics.record_preempted();
        self.batcher.push_resume(run);
    }

    fn maybe_finish(&mut self, slot: usize, run: Running) {
        match run.should_stop(self.engine.kv.remaining(slot)) {
            Some(reason) => {
                self.engine.kv.free(slot);
                let resp = run.into_response(reason);
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            }
            None => {
                self.running.insert(slot, run);
            }
        }
    }

    /// Run until the queue and all slots drain; returns all responses.
    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Drain the per-token stream events accumulated since the last call.
    pub fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        std::mem::take(&mut self.token_events)
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Cancel one request (client disconnect): drops it from the waiting
    /// queue (fresh or preempted), or frees its KV lane if running.
    /// Returns true if the request was found anywhere.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(req) = self.batcher.remove(id) {
            self.metrics.record_cancelled();
            self.finished.push(Response::cancelled(req.id, req.echo_text));
            return true;
        }
        if let Some(run) = self.batcher.remove_resume(id) {
            self.metrics.record_cancelled();
            self.finished.push(run.into_response(FinishReason::Cancelled));
            return true;
        }
        let slot = self
            .running
            .iter()
            .find(|(_, run)| run.request.id == id)
            .map(|(&slot, _)| slot);
        match slot {
            Some(slot) => {
                let run = self.running.remove(&slot).unwrap();
                self.engine.kv.free(slot);
                self.metrics.record_cancelled();
                self.finished.push(run.into_response(FinishReason::Cancelled));
                true
            }
            None => false,
        }
    }

    /// Cancel everything in flight (server shutdown).
    pub fn cancel_all(&mut self) {
        let slots: Vec<usize> = self.running.keys().copied().collect();
        for slot in slots {
            let run = self.running.remove(&slot).unwrap();
            self.engine.kv.free(slot);
            self.metrics.record_cancelled();
            self.finished.push(run.into_response(FinishReason::Cancelled));
        }
        while let Some(next) = self.batcher.pop_next() {
            self.metrics.record_cancelled();
            match next {
                Admit::New(req) => {
                    self.finished.push(Response::cancelled(req.id, req.echo_text));
                }
                Admit::Resume(run) => {
                    self.finished.push(run.into_response(FinishReason::Cancelled));
                }
            }
        }
    }
}
