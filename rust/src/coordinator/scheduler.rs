//! The continuous-batching step loop (vLLM-style), paged edition: each
//! step admits work while a lane *and enough KV blocks* are available
//! (prefill-priority keeps TTFT low), grows running sequences' block
//! tables for the step's decode writes — preempting the youngest
//! running sequence back to the queue when the pool runs dry — then
//! runs one batched decode step over every running slot.
//!
//! Preemption frees the victim's non-shared blocks (full prompt blocks
//! are donated to the prefix cache first, so the resume re-prefill can
//! share them back) and requeues the sequence with everything it has
//! generated; resuming re-prefills `prompt ++ generated`, whose
//! last-position logits are exactly the decode step the preemption
//! interrupted — in fp and statically-quantized modes the resumed token
//! stream is bit-identical to an uninterrupted run (per-row arithmetic
//! is batch-independent; the paged_kv preemption test pins this). In
//! *dynamic* per-tensor modes (ptd/ptk) the re-prefill's dynamic ranges
//! span a different activation batch, so resumed tokens may round
//! differently — same model, same semantics, different batch shape.
//! Starvation-freedom: victims are always strictly younger than the
//! oldest running sequence (which therefore completes), and the batcher
//! admits by submission age across fresh and preempted work.
//!
//! Fault isolation: `step()` returning `Err` means the *engine* failed
//! (a batched decode aborted — systemic, affects every slot). Anything
//! wrong with a single request — an oversized or empty prompt, an
//! out-of-vocab token, a prefill that rejects its input — is converted
//! at admission into a `FinishReason::Error` response in `finished` and
//! the loop keeps serving everyone else.
//!
//! Fault *recovery* (runtime::faults): injected engine faults are
//! retried in place with bounded exponential backoff — a failed engine
//! call leaves the cache untouched, so a retry replays the identical
//! computation. When retries exhaust, the affected sequences are
//! preempted back to the resume queue (the bit-identical re-prefill
//! path above), and a *persistent* fault first walks the degradation
//! ladder down a rung: device-split → host-roundtrip → interpreter.
//! Per-request deadlines are swept at the top of each step *and again
//! between the prefill phase and the decode batch* (a deadline that
//! lapses during a long prefill must never cost a decode step), and
//! `drain()` turns the loop into a graceful-shutdown mode that finishes
//! accepted work while rejecting new submissions.
//!
//! Chunked prefill (`set_prefill_chunk`): with a per-step token budget
//! set, a long prompt no longer stalls every co-batched decode behind
//! one monolithic prefill. Admission allocates the lane as usual but
//! parks the sequence on the `prefilling` queue; each step then spends
//! at most `budget` prompt tokens across pending prefills — round-robin
//! via `Engine::prefill_chunk`, which extends the slot's KV past the
//! pinned cushion-prefix run — before the decode batch. The final
//! chunk's logits seed decode exactly like a single-shot prefill, and
//! the prompt's blocks are published to the prefix cache only then. In
//! fp/static modes the chunked token stream is bit-identical to the
//! unchunked one (masked attention keys carry exactly zero softmax
//! mass); dynamic per-tensor modes see per-chunk activation batches —
//! the same tolerance caveat as preemption-resume above. Chunking is
//! engine-gated (`supports_chunked_prefill`): prompts on unsupported
//! paths, prompts within one budget, and full-cap prompts (whose
//! mid-prefill lane would trip the co-batched decode's `off < cap`
//! bound) take the single-shot path unchanged. A downgrade drains the
//! prefilling queue back to the batcher first, so no sequence straddles
//! two execution modes mid-prompt.

use std::collections::{HashMap, VecDeque};

use crate::data::PAD;
use crate::runtime::trace;

use super::batcher::{Admit, Batcher, Running};
use super::engine::Engine;
use super::metrics::Metrics;
use super::request::{FinishReason, Request, RequestId, Response};

/// Bounded-retry policy for injected engine faults: total attempts per
/// engine call, with exponential backoff between them (1ms, then 2ms).
const RETRY_ATTEMPTS: usize = 3;
const RETRY_BACKOFF: std::time::Duration = std::time::Duration::from_millis(1);

/// A sequence mid chunked-prefill: it owns a KV lane (`run.slot`) with
/// `done` of `tokens` already written; it is in neither the batcher nor
/// `running` until its final chunk lands. A fresh admission carries its
/// prompt here; a preempted resume carries `prompt ++ generated` (and
/// keeps its `donated` holds until the re-prefill completes).
struct Prefilling {
    run: Running,
    tokens: Vec<i32>,
    done: usize,
}

pub struct Scheduler {
    pub engine: Engine,
    pub batcher: Batcher,
    pub metrics: Metrics,
    running: HashMap<usize, Running>, // slot -> running request
    /// Sequences mid chunked-prefill, oldest-admitted first.
    prefilling: VecDeque<Prefilling>,
    /// Per-step chunked-prefill token budget (`None` = single-shot).
    prefill_chunk: Option<usize>,
    finished: Vec<Response>,
    /// (request, token) pairs in generation order since the last
    /// `take_token_events` — the streaming front end drains these to
    /// emit one wire line per generated token. Only requests submitted
    /// with `stream: true` record events, so offline consumers that
    /// never drain (benches, run_to_completion) accumulate nothing.
    token_events: Vec<(RequestId, i32)>,
    /// Graceful-shutdown drain: when set, new submissions are rejected
    /// with "overloaded" while already-accepted work (queued, preempted,
    /// running) is finished normally.
    draining: bool,
    /// Degradation-ladder rung this scheduler has fallen to: 0
    /// device-split, 1 host-roundtrip, 2 interpreter. Never climbs back
    /// up within a process — a path that faulted persistently stays
    /// shed.
    rung: u32,
    /// Activation-health sampling period: every Nth decode step meters
    /// the quantization sites' absmax/clip counts (`runtime::trace`
    /// act gauges). 0 disables sampling entirely.
    act_sample: u32,
    /// Decode steps attempted so far — drives the sampling cadence
    /// deterministically (step counter, never wall-clock).
    decode_steps: u64,
}

impl Scheduler {
    pub fn new(engine: Engine) -> Self {
        let mut metrics = Metrics::new();
        metrics.pool_blocks_total = engine.kv.total_blocks();
        Self {
            engine,
            batcher: Batcher::new(),
            metrics,
            running: HashMap::new(),
            prefilling: VecDeque::new(),
            prefill_chunk: None,
            finished: Vec::new(),
            token_events: Vec::new(),
            draining: false,
            rung: 0,
            act_sample: 16,
            decode_steps: 0,
        }
    }

    /// Set the activation-health sampling period: every `n`th decode
    /// step runs with quantization-site metering armed (absmax + clip
    /// rate against the static ranges). `0` disables sampling. The
    /// default (16) keeps the hot path unmetered ~94% of the time.
    pub fn set_act_sample(&mut self, n: u32) {
        self.act_sample = n;
    }

    pub fn act_sample(&self) -> u32 {
        self.act_sample
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        self.batcher.submit(prompt, max_new)
    }

    pub fn submit_request(&mut self, r: Request) {
        if self.draining {
            self.metrics.record_rejected();
            trace::instant(
                "reject",
                "sched",
                Some(r.id),
                &[("why", "overloaded".to_string())],
            );
            self.finished
                .push(Response::rejection(r.id, r.echo_text, "overloaded".into()));
            return;
        }
        self.batcher.submit_request(r);
    }

    /// Enter drain mode (graceful shutdown): accepted work finishes
    /// normally, new submissions are rejected with "overloaded". The
    /// server steps the scheduler until `has_work()` clears, then exits.
    pub fn drain(&mut self) {
        if !self.draining {
            trace::instant("drain", "sched", None, &[]);
        }
        self.draining = true;
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// The degradation-ladder rung currently in effect (0 = full
    /// device-split path).
    pub fn rung(&self) -> u32 {
        self.rung
    }

    pub fn has_work(&self) -> bool {
        self.batcher.waiting() > 0
            || !self.running.is_empty()
            || !self.prefilling.is_empty()
    }

    /// Enable scheduler-budgeted chunked prefill: each step prefills at
    /// most `budget` prompt tokens across pending chunked sequences
    /// before the decode batch, so one long prompt cannot stall the
    /// co-batched decodes for longer than the budget. `None` (or 0)
    /// restores single-shot prefill.
    pub fn set_prefill_chunk(&mut self, budget: Option<usize>) {
        self.prefill_chunk = budget.filter(|&b| b > 0);
    }

    pub fn prefill_chunk(&self) -> Option<usize> {
        self.prefill_chunk
    }

    /// Whether a prefill of `tokens_len` tokens should go through the
    /// chunked path: a budget is set, the prompt is longer than one
    /// budget (shorter ones cost no more than a chunk anyway and keep
    /// their bucketed/sampled fast path), the engine's execution mode
    /// supports it, and the prompt does not fill the KV space to the
    /// brim — a mid-prefill lane reports its full prompt as `tok_len`,
    /// so a full-cap prompt would trip the co-batched decode's
    /// per-lane `off < cap` bound before its final chunk landed.
    fn chunked_admissible(&self, tokens_len: usize) -> bool {
        let Some(budget) = self.prefill_chunk else { return false };
        tokens_len > budget
            && self.engine.supports_chunked_prefill()
            && self.engine.kv.m_max + tokens_len < self.engine.kv.cap
    }

    /// Why `req` can never be served, if so: checked before a KV lane is
    /// committed. `None` means the request is admissible — though it may
    /// still have to *wait* for lanes or blocks. A prompt of exactly
    /// `cap - m_max` tokens is admissible with zero decode room: it is
    /// served its prefill token and finished with `Length` immediately
    /// (the pre-paging `alloc` admitted it and relied on overflow
    /// asserts downstream).
    pub fn admission_error(&self, req: &Request) -> Option<String> {
        let m = &self.engine.session.manifest;
        let kv = &self.engine.kv;
        if req.prompt.is_empty() {
            return Some("empty prompt".to_string());
        }
        if req.prompt.len() > m.seq_len {
            return Some(format!(
                "prompt too long: {} tokens exceeds the prefill window {}",
                req.prompt.len(),
                m.seq_len
            ));
        }
        if kv.m_max + req.prompt.len() > kv.cap {
            return Some(format!(
                "prompt does not fit the kv space: {} prefix + {} prompt > cap {}",
                kv.m_max,
                req.prompt.len(),
                kv.cap
            ));
        }
        if let Some(&t) = req
            .prompt
            .iter()
            .find(|&&t| t < 0 || t as usize >= m.vocab)
        {
            return Some(format!("token {t} outside vocab [0, {})", m.vocab));
        }
        None
    }

    /// Finish `req` with a per-request error response (never an engine
    /// error): the "one bad request crashes the fleet" class dies here.
    fn reject(&mut self, req: Request, why: String) {
        log::debug!("request {} rejected: {why}", req.id);
        let resp = Response::rejection(req.id, req.echo_text, why);
        self.metrics.record_finished(&resp);
        self.finished.push(resp);
    }

    /// One scheduler step. Returns the number of tokens produced.
    /// `Err` is reserved for engine-level (batch-wide) failures.
    pub fn step(&mut self) -> crate::Result<usize> {
        let mut produced = 0;

        // 0) deadline sweep before admission: an expired request must
        //    not cost a prefill
        self.expire_deadlines();

        // 1) admission, oldest-submission first across fresh requests
        //    and preempted resumes. Inadmissible requests are rejected
        //    even when nothing can be admitted — a poisoned queue must
        //    drain instead of festering behind long runners. Admission
        //    stops when a lane or the block pool says "wait".
        loop {
            let Some(next) = self.batcher.pop_next() else { break };
            match next {
                Admit::New(req) => {
                    if let Some(why) = self.admission_error(&req) {
                        self.reject(req, why);
                        continue;
                    }
                    if self.engine.kv.free_count() == 0
                        || !self.engine.kv.can_admit(&req.prompt, req.max_new_tokens)
                    {
                        self.batcher.push_front(req);
                        break;
                    }
                    let Some(slot) =
                        self.engine.kv.alloc_with_prompt(req.id, &req.prompt)
                    else {
                        // the pool moved between can_admit and alloc
                        // (conservative math) — wait, don't reject
                        self.batcher.push_front(req);
                        break;
                    };
                    trace::instant(
                        "admit",
                        "sched",
                        Some(req.id),
                        &[
                            ("prompt", req.prompt.len().to_string()),
                            ("max_new", req.max_new_tokens.to_string()),
                        ],
                    );
                    if self.chunked_admissible(req.prompt.len()) {
                        // lane and blocks are committed; the prompt is
                        // prefilled by the budgeted chunk phase below
                        let run = Running::new(req, slot);
                        let tokens = run.request.prompt.clone();
                        self.prefilling.push_back(Prefilling {
                            run,
                            tokens,
                            done: 0,
                        });
                        continue;
                    }
                    match self.admit_prefill(slot, Running::new(req, slot))? {
                        Some(n) => produced += n,
                        // fault-requeued: stop admitting this step so
                        // the retry happens under the next step's (maybe
                        // downgraded) mode
                        None => break,
                    }
                }
                Admit::Resume(run) => {
                    let tokens = run.resume_tokens();
                    // the real remaining budget: a resume one token shy
                    // of max_new needs no decode room beyond its prefill
                    let budget = run
                        .request
                        .max_new_tokens
                        .saturating_sub(run.generated.len())
                        .max(1);
                    if self.engine.kv.free_count() == 0
                        || !self.engine.kv.can_admit(&tokens, budget)
                    {
                        self.batcher.push_resume(run);
                        break;
                    }
                    let Some(slot) = self
                        .engine
                        .kv
                        .alloc_with_prompt(run.request.id, &tokens)
                    else {
                        self.batcher.push_resume(run);
                        break;
                    };
                    trace::instant(
                        "resume",
                        "sched",
                        Some(run.request.id),
                        &[("generated", run.generated.len().to_string())],
                    );
                    if self.chunked_admissible(tokens.len()) {
                        let mut run = run;
                        run.slot = slot;
                        // `donated` holds stay tracked until the final
                        // chunk lands (resume_prefill clears on success)
                        self.prefilling.push_back(Prefilling {
                            run,
                            tokens,
                            done: 0,
                        });
                        continue;
                    }
                    match self.resume_prefill(slot, run, &tokens)? {
                        Some(n) => produced += n,
                        None => break,
                    }
                }
            }
        }

        // 1b) budgeted chunked-prefill phase: extend pending prompts by
        //     at most `prefill_chunk` tokens in total, round-robin
        produced += self.run_prefill_chunks()?;

        // 1c) deadline re-sweep: a deadline that lapsed during the
        //     prefill phase must not cost a decode step
        self.expire_deadlines();

        // 2) every running sequence must be able to cache the token this
        //    step feeds it; preempt the youngest when the pool is dry
        self.ensure_decode_room();

        // 3) batched decode over all running slots
        if !self.running.is_empty() {
            let b = self.engine.kv.n_slots;
            let mut tokens = vec![PAD; b];
            for (&slot, run) in &self.running {
                tokens[slot] = *run.generated.last().unwrap();
            }
            let t0 = std::time::Instant::now();
            // arm activation-health metering on every act_sample'th
            // step: the cadence is a step counter, so which steps are
            // sampled is deterministic under a fixed seed
            let sampled = self.act_sample > 0
                && self.decode_steps % self.act_sample as u64 == 0;
            self.decode_steps += 1;
            if sampled {
                trace::act_begin();
            }
            let span = trace::begin(
                "decode_step",
                "sched",
                None,
                &[("batch", self.running.len().to_string())],
            );
            // meter the step's host-boundary traffic alongside its
            // latency: the bytes-per-step gauges in the serve metrics.
            // Collective (shard-to-shard) traffic is metered separately
            // on its own counters, plus the group run's execute skew.
            let ((res, xfer), coll) = crate::runtime::collective::measure(|| {
                crate::runtime::transfer::measure(|| {
                    self.with_retry("batched decode", |eng| eng.decode_step(&tokens))
                })
            });
            let act = if sampled { Some(trace::act_end()) } else { None };
            let skew = if self.engine.n_shards() > 1 {
                crate::runtime::collective::last_skew_seconds()
            } else {
                0.0
            };
            match res {
                Ok(next) => {
                    trace::end(span, &[]);
                    let dt = t0.elapsed().as_secs_f64();
                    self.metrics
                        .record_decode(dt, self.running.len(), xfer, coll, skew);
                    crate::runtime::transfer::trace_delta(&xfer);
                    // a sampled step that faulted is discarded (the Err
                    // arm): a half-executed batch's absmax is not a
                    // health signal
                    if let Some(s) = act.filter(|s| s.total > 0) {
                        self.metrics.record_act_sample(s);
                        trace::instant(
                            "act_sample",
                            "quant",
                            None,
                            &[
                                ("absmax", format!("{:.4}", s.absmax)),
                                ("clip_rate", format!("{:.6}", s.clip_rate())),
                            ],
                        );
                    }

                    let slots: Vec<usize> = self.running.keys().copied().collect();
                    for slot in slots {
                        let mut run = self.running.remove(&slot).unwrap();
                        // the token we just fed is now cached at
                        // position tok_len
                        self.engine.kv.push_token(slot);
                        run.push_token(next[slot]);
                        if run.request.stream {
                            self.token_events.push((run.request.id, next[slot]));
                        }
                        produced += 1;
                        self.maybe_finish(slot, run);
                    }
                }
                Err(e) => {
                    trace::end(span, &[("error", "1".to_string())]);
                    self.recover_decode_fault(e)?
                }
            }
        }
        if crate::runtime::faults::armed() {
            self.metrics
                .record_faults_injected(crate::runtime::faults::stats().total());
        }
        self.metrics.record_pool(self.engine.kv.pool_stats());
        Ok(produced)
    }

    /// Spend this step's chunked-prefill token budget across the
    /// `prefilling` queue, round-robin: pop the oldest entry, prefill
    /// up to the remaining budget of its pending tokens, and either
    /// finish it (final chunk — its logits seed decode exactly like a
    /// single-shot prefill) or park it at the back. Fault handling
    /// mirrors `admit_prefill`/`resume_prefill`: the lane is freed and
    /// the sequence requeued through the batcher, so the retry (under a
    /// maybe-downgraded engine) re-decides chunk eligibility.
    fn run_prefill_chunks(&mut self) -> crate::Result<usize> {
        let Some(chunk_budget) = self.prefill_chunk else { return Ok(0) };
        let mut produced = 0;
        let mut budget = chunk_budget;
        while budget > 0 {
            let Some(mut p) = self.prefilling.pop_front() else { break };
            let take = budget.min(p.tokens.len() - p.done);
            let chunk: Vec<i32> = p.tokens[p.done..p.done + take].to_vec();
            let (slot, done) = (p.run.slot, p.done);
            let span = trace::begin(
                "prefill_chunk",
                "sched",
                Some(p.run.request.id),
                // token progress through the prompt: (done+take)/total
                &[("progress", format!("{}/{}", done + take, p.tokens.len()))],
            );
            let t0 = std::time::Instant::now();
            match self
                .with_retry("prefill chunk", |eng| eng.prefill_chunk(slot, &chunk, done))
            {
                Ok(Some(first)) => {
                    trace::end(span, &[("final", "1".to_string())]);
                    self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                    budget -= take;
                    // a resume's donated blocks were re-shared into the
                    // new table at admission; ordinary cache entries now
                    p.run.donated.clear();
                    p.run.push_token(first);
                    if p.run.request.stream {
                        self.token_events.push((p.run.request.id, first));
                    }
                    produced += 1;
                    self.maybe_finish(slot, p.run);
                }
                Ok(None) => {
                    trace::end(span, &[]);
                    self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                    budget -= take;
                    p.done += take;
                    self.prefilling.push_back(p);
                }
                Err(e) => {
                    trace::end(span, &[("error", "1".to_string())]);
                    // the partial prefix dies with the lane: no block is
                    // fully written from this sequence's perspective, so
                    // a plain free (no donation) is the only safe exit —
                    // original resume `donated` holds stay tracked on
                    // the run for the eventual cancel/deadline drop
                    self.engine.kv.free(slot);
                    if crate::runtime::faults::is_replica_down(&e) {
                        self.requeue_chunked(p);
                        return Err(e);
                    }
                    let retryable = match crate::runtime::faults::classify(&e) {
                        Some((_, true)) => true,
                        Some((_, false)) => self.downgrade(),
                        None => false,
                    };
                    if retryable {
                        log::warn!(
                            "chunked prefill of request {} fault-injected; \
                             requeued: {e:#}",
                            p.run.request.id
                        );
                        self.requeue_chunked(p);
                        return Ok(produced);
                    }
                    if p.run.generated.is_empty() {
                        self.reject(
                            p.run.request,
                            format!("prefill failed: {e:#}"),
                        );
                    } else {
                        self.engine.kv.drop_cached(&p.run.donated);
                        let id = p.run.request.id;
                        log::debug!("chunked resume of request {id} failed: {e:#}");
                        let resp = p.run.into_response(FinishReason::Error(
                            format!("resume failed: {e:#}"),
                        ));
                        self.metrics.record_finished(&resp);
                        self.finished.push(resp);
                    }
                }
            }
        }
        Ok(produced)
    }

    /// Send a mid-prefill sequence (lane already freed) back through the
    /// batcher: a fresh admission requeues as a fresh request, a
    /// preempted resume goes back to the resume queue with its donated
    /// holds intact.
    fn requeue_chunked(&mut self, p: Prefilling) {
        if p.run.generated.is_empty() {
            self.batcher.push_front(p.run.request);
        } else {
            self.batcher.push_resume(p.run);
        }
    }

    /// Run `call` against the engine under the bounded-retry policy:
    /// *transient injected* faults are retried with exponential backoff
    /// (a failed engine call leaves the KV cache untouched — the engine
    /// clones its cache argument — so a retry replays the identical
    /// computation). Persistent faults and genuine engine errors return
    /// immediately; exhausted retries return the last error.
    fn with_retry<T>(
        &mut self,
        what: &str,
        mut call: impl FnMut(&mut Engine) -> crate::Result<T>,
    ) -> crate::Result<T> {
        let mut attempt = 1;
        loop {
            match call(&mut self.engine) {
                Ok(v) => return Ok(v),
                Err(e) => match crate::runtime::faults::classify(&e) {
                    Some((op, true)) if attempt < RETRY_ATTEMPTS => {
                        self.metrics.record_retry(op.as_str());
                        trace::instant(
                            "retry",
                            "fault",
                            None,
                            &[
                                ("op", op.as_str().to_string()),
                                ("attempt", attempt.to_string()),
                            ],
                        );
                        log::debug!(
                            "{what}: transient {} fault (attempt \
                             {attempt}/{RETRY_ATTEMPTS}), backing off: {e:#}",
                            op.as_str()
                        );
                        std::thread::sleep(RETRY_BACKOFF * (1u32 << (attempt - 1)));
                        attempt += 1;
                    }
                    _ => return Err(e),
                },
            }
        }
    }

    /// A batched decode exhausted its retries. Injected faults are
    /// recoverable: a persistent one first takes the degradation ladder
    /// down a rung, then every running sequence is preempted back to
    /// the resume queue — the resume path re-prefills bit-identically
    /// (fp/static modes), so the fault costs latency, not correctness.
    /// Genuine (non-injected) engine errors still propagate: retrying a
    /// deterministic bug forever would only hide it.
    fn recover_decode_fault(&mut self, e: anyhow::Error) -> crate::Result<()> {
        let Some((op, transient)) = crate::runtime::faults::classify(&e) else {
            return Err(e);
        };
        log::warn!(
            "batched decode faulted past retries ({} {}): requeueing {} \
             running sequence(s): {e:#}",
            if transient { "transient" } else { "persistent" },
            op.as_str(),
            self.running.len()
        );
        if !transient && !self.downgrade() {
            // ladder floor and the fault persists: fail the affected
            // batch honestly rather than spinning on it forever. The
            // floor counter is the router's health signal — a replica
            // erroring work at the ladder floor is as good as broken.
            let slots: Vec<usize> = self.running.keys().copied().collect();
            for slot in slots {
                let run = self.running.remove(&slot).unwrap();
                self.engine.kv.free(slot);
                self.metrics.record_floor_error();
                trace::instant(
                    "floor_error",
                    "fault",
                    Some(run.request.id),
                    &[("rung", self.rung.to_string())],
                );
                let resp = run.into_response(FinishReason::Error(format!(
                    "decode failed past the ladder floor: {e:#}"
                )));
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            }
            return Ok(());
        }
        let slots: Vec<usize> = self.running.keys().copied().collect();
        for slot in slots {
            self.preempt_or_finish(slot);
        }
        Ok(())
    }

    /// One rung down the degradation ladder on a persistent fault:
    /// rung 0 device-split → 1 host-roundtrip → 2 interpreter. Every
    /// running sequence is preempted first so its resume re-prefills
    /// entirely under the downgraded mode (no sequence straddles two
    /// execution modes mid-stream). Returns false at the ladder floor.
    fn downgrade(&mut self) -> bool {
        if self.rung >= 2 {
            return false;
        }
        let slots: Vec<usize> = self.running.keys().copied().collect();
        for slot in slots {
            self.preempt_or_finish(slot);
        }
        // drain mid-prefill sequences back to the batcher too: their
        // next attempt re-decides chunk eligibility under the new mode,
        // so no sequence straddles two execution modes mid-prompt
        let pending = std::mem::take(&mut self.prefilling);
        for p in pending {
            self.engine.kv.free(p.run.slot);
            self.requeue_chunked(p);
        }
        self.rung += 1;
        let mode = match self.rung {
            1 => {
                self.engine.set_host_roundtrip(true);
                "host-roundtrip"
            }
            _ => {
                self.engine.session.registry.force_interp(true);
                "interpreter"
            }
        };
        crate::runtime::faults::set_rung(self.rung);
        self.metrics.record_downgrade(self.rung);
        trace::instant(
            "downgrade",
            "fault",
            None,
            &[("rung", self.rung.to_string()), ("mode", mode.to_string())],
        );
        log::warn!(
            "persistent fault: engine downgraded to rung {} ({mode}); \
             serving continues",
            self.rung
        );
        true
    }

    /// Preempt `slot` for fault recovery when its resume can re-prefill,
    /// else finish it with `Length` (the same policy pool-pressure
    /// preemption applies to unresumable sequences).
    fn preempt_or_finish(&mut self, slot: usize) {
        let seq_len = self.engine.session.manifest.seq_len;
        let run = &self.running[&slot];
        if run.request.prompt.len() + run.generated.len() <= seq_len {
            self.preempt(slot);
        } else {
            let run = self.running.remove(&slot).unwrap();
            self.engine.kv.free(slot);
            let resp = run.into_response(FinishReason::Length);
            self.metrics.record_finished(&resp);
            self.finished.push(resp);
        }
    }

    /// Kill every request whose deadline has passed — queued, preempted,
    /// or running — with `FinishReason::Error("deadline")`, freeing its
    /// lane, pool blocks, and any preemption-donated cache entries.
    fn expire_deadlines(&mut self) {
        let now = std::time::Instant::now();
        let (fresh, preempted) = self.batcher.expire_where(|r| r.expired(now));
        for req in fresh {
            self.metrics.record_deadline_expired();
            let resp = Response::rejection(req.id, req.echo_text, "deadline".into());
            self.metrics.record_finished(&resp);
            self.finished.push(resp);
        }
        for run in preempted {
            self.metrics.record_deadline_expired();
            self.engine.kv.drop_cached(&run.donated);
            let resp = run.into_response(FinishReason::Error("deadline".into()));
            self.metrics.record_finished(&resp);
            self.finished.push(resp);
        }
        let expired: Vec<usize> = self
            .running
            .iter()
            .filter(|(_, run)| run.request.expired(now))
            .map(|(&slot, _)| slot)
            .collect();
        for slot in expired {
            let run = self.running.remove(&slot).unwrap();
            self.engine.kv.free(slot);
            self.metrics.record_deadline_expired();
            let resp = run.into_response(FinishReason::Error("deadline".into()));
            self.metrics.record_finished(&resp);
            self.finished.push(resp);
        }
        // mid-chunked-prefill sequences hold a lane (and, for resumes,
        // donated prefix-cache entries) without being queued or running
        let pending = std::mem::take(&mut self.prefilling);
        for p in pending {
            if p.run.request.expired(now) {
                self.engine.kv.free(p.run.slot);
                self.engine.kv.drop_cached(&p.run.donated);
                self.metrics.record_deadline_expired();
                let resp =
                    p.run.into_response(FinishReason::Error("deadline".into()));
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            } else {
                self.prefilling.push_back(p);
            }
        }
    }

    /// Prefill a freshly admitted request; returns `Ok(Some(tokens
    /// produced))` (1 on success), or `Ok(None)` when an injected fault
    /// survived the retries and the request was requeued — the caller
    /// must stop admitting for this step. A whole-replica kill
    /// (`faults::is_replica_down`) requeues the request and propagates
    /// `Err`: the engine is dead, so the failure is engine-level, not
    /// request-level — the router's fault domain evacuates the queue.
    fn admit_prefill(
        &mut self,
        slot: usize,
        mut running: Running,
    ) -> crate::Result<Option<usize>> {
        let span = trace::begin(
            "prefill",
            "sched",
            Some(running.request.id),
            &[("tokens", running.request.prompt.len().to_string())],
        );
        let t0 = std::time::Instant::now();
        match self.with_retry("prefill", |eng| eng.prefill(slot, &running.request.prompt))
        {
            Ok(first) => {
                trace::end(span, &[]);
                self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                // NOTE: `first` is generated but its KV is not cached
                // yet; kv.tok_len stays at prompt_len until the decode
                // step that feeds it (the cache invariant: tok_len ==
                // cached tokens).
                running.push_token(first);
                if running.request.stream {
                    self.token_events.push((running.request.id, first));
                }
                self.maybe_finish(slot, running);
                Ok(Some(1))
            }
            Err(e) => {
                trace::end(span, &[("error", "1".to_string())]);
                self.engine.kv.free(slot);
                if crate::runtime::faults::is_replica_down(&e) {
                    self.batcher.push_front(running.request);
                    return Err(e);
                }
                let retryable = match crate::runtime::faults::classify(&e) {
                    Some((_, true)) => true,
                    Some((_, false)) => self.downgrade(),
                    None => false,
                };
                if retryable {
                    log::warn!(
                        "prefill of request {} fault-injected; requeued: {e:#}",
                        running.request.id
                    );
                    self.batcher.push_front(running.request);
                    return Ok(None);
                }
                // prefill consumes only this request's input, so its
                // failure is request-scoped: free the lane, error the
                // request, keep the engine alive.
                self.reject(running.request, format!("prefill failed: {e:#}"));
                Ok(Some(0))
            }
        }
    }

    /// Re-prefill a preempted sequence (`prompt ++ generated`) and
    /// continue it; returns `Ok(Some(tokens produced))` (1 on success),
    /// or `Ok(None)` when an injected fault requeued the sequence. A
    /// whole-replica kill requeues the sequence and propagates `Err`
    /// (see `admit_prefill`) — the router migrates it to a live replica.
    fn resume_prefill(
        &mut self,
        slot: usize,
        mut run: Running,
        tokens: &[i32],
    ) -> crate::Result<Option<usize>> {
        let span = trace::begin(
            "prefill",
            "sched",
            Some(run.request.id),
            &[
                ("tokens", tokens.len().to_string()),
                ("resume", "1".to_string()),
            ],
        );
        let t0 = std::time::Instant::now();
        match self.with_retry("resume prefill", |eng| eng.prefill(slot, tokens)) {
            Ok(next) => {
                trace::end(span, &[]);
                self.metrics.record_prefill(t0.elapsed().as_secs_f64());
                run.slot = slot;
                // the blocks donated at preemption were re-shared into
                // the new table by alloc_with_prompt (or evicted);
                // either way they are ordinary cache entries now, no
                // longer this run's to drop
                run.donated.clear();
                run.push_token(next);
                if run.request.stream {
                    self.token_events.push((run.request.id, next));
                }
                self.maybe_finish(slot, run);
                Ok(Some(1))
            }
            Err(e) => {
                trace::end(span, &[("error", "1".to_string())]);
                // this attempt's free may donate *new* full blocks (the
                // generated suffix); track them with the originals so a
                // later cancel/deadline drops exactly one hold per entry
                let newly = self.engine.kv.free_donating(slot);
                run.donated.extend(newly);
                if crate::runtime::faults::is_replica_down(&e) {
                    self.batcher.push_resume(run);
                    return Err(e);
                }
                let retryable = match crate::runtime::faults::classify(&e) {
                    Some((_, true)) => true,
                    Some((_, false)) => self.downgrade(),
                    None => false,
                };
                if retryable {
                    log::warn!(
                        "resume of request {} fault-injected; requeued: {e:#}",
                        run.request.id
                    );
                    self.batcher.push_resume(run);
                    return Ok(None);
                }
                self.engine.kv.drop_cached(&run.donated);
                let id = run.request.id;
                log::debug!("resume of request {id} failed: {e:#}");
                let resp = run
                    .into_response(FinishReason::Error(format!("resume failed: {e:#}")));
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
                Ok(Some(0))
            }
        }
    }

    /// Guarantee each running sequence a block for this step's KV write,
    /// preempting the youngest running sequence (never the oldest — the
    /// anti-starvation invariant) while the pool is dry. When no other
    /// victim exists the starved sequence preempts *itself* (waiting in
    /// the resume queue costs latency, not tokens; progress is
    /// guaranteed because a lone resume always fits the pool floor and
    /// each resume cycle generates at least its prefill token). Only a
    /// sequence that can never be resumed — its re-prefill would exceed
    /// the prefill window — is finished early with `Length`.
    fn ensure_decode_room(&mut self) {
        let seq_len = self.engine.session.manifest.seq_len;
        let mut slots: Vec<usize> = self.running.keys().copied().collect();
        slots.sort_unstable();
        for slot in slots {
            if !self.running.contains_key(&slot) {
                continue; // preempted while making room for an earlier slot
            }
            loop {
                if self.engine.kv.ensure_append(slot) {
                    break;
                }
                match self.pick_victim() {
                    Some(victim) => {
                        let preempted_self = victim == slot;
                        self.preempt(victim);
                        if preempted_self {
                            break;
                        }
                    }
                    None => {
                        let run = &self.running[&slot];
                        let resumable = run.request.prompt.len()
                            + run.generated.len()
                            <= seq_len;
                        if resumable {
                            self.preempt(slot);
                        } else {
                            // unresumable and the pool cannot grow it:
                            // the only honest terminal is truncation
                            let run = self.running.remove(&slot).unwrap();
                            self.engine.kv.free(slot);
                            let resp = run.into_response(FinishReason::Length);
                            self.metrics.record_finished(&resp);
                            self.finished.push(resp);
                        }
                        break;
                    }
                }
            }
        }
    }

    /// The preemption victim: the youngest-submitted running sequence
    /// that can *usefully* be resumed later, excluding the oldest
    /// running sequence. Useful means the re-prefill (`prompt ++
    /// generated`) fits the prefill window with room to spare: a
    /// sequence sitting exactly at `seq_len` would resume only to
    /// re-prefill the entire window — the most expensive recompute the
    /// engine can do — for tokens its very next decode step delivers
    /// without any preemption, so it is left running (the off-by-one
    /// was `<= seq_len`).
    fn pick_victim(&self) -> Option<usize> {
        if self.running.len() < 2 {
            return None;
        }
        let seq_len = self.engine.session.manifest.seq_len;
        let oldest = self
            .running
            .iter()
            .min_by_key(|(_, r)| (r.request.submitted, r.request.id))
            .map(|(&s, _)| s)?;
        self.running
            .iter()
            .filter(|&(&s, r)| {
                s != oldest
                    && r.request.prompt.len() + r.generated.len() + 1 <= seq_len
            })
            .max_by_key(|(_, r)| (r.request.submitted, r.request.id))
            .map(|(&s, _)| s)
    }

    /// Move a running sequence back to the queue: free its lane and
    /// non-shared blocks (full prompt blocks are donated to the prefix
    /// cache on the way out) and let it resume by re-prefill.
    fn preempt(&mut self, slot: usize) {
        let mut run = self.running.remove(&slot).unwrap();
        log::debug!(
            "preempting request {} ({} generated)",
            run.request.id,
            run.generated.len()
        );
        // remember which blocks this sequence donated to the prefix
        // cache on the way out: a cancel while it waits for resume must
        // drop exactly these entries (nothing else accounts for them)
        run.donated = self.engine.kv.free_donating(slot);
        self.metrics.record_preempted();
        trace::instant(
            "preempt",
            "sched",
            Some(run.request.id),
            &[("generated", run.generated.len().to_string())],
        );
        self.batcher.push_resume(run);
    }

    fn maybe_finish(&mut self, slot: usize, run: Running) {
        match run.should_stop(self.engine.kv.remaining(slot)) {
            Some(reason) => {
                self.engine.kv.free(slot);
                trace::instant(
                    "finish",
                    "sched",
                    Some(run.request.id),
                    &[
                        ("reason", reason.as_str().to_string()),
                        ("generated", run.generated.len().to_string()),
                    ],
                );
                let resp = run.into_response(reason);
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            }
            None => {
                self.running.insert(slot, run);
            }
        }
    }

    /// Tear this scheduler down for failover: every running sequence is
    /// released from its KV lane and every queued work item — fresh and
    /// preempted alike — is pulled out, so the router can reconstruct
    /// the whole lot on healthy replicas via the `prompt ++ generated`
    /// resume path. All of *this* pool's bookkeeping is settled here:
    /// lanes freed, preemption-donated prefix-cache holds dropped
    /// exactly once and cleared (the hashes mean nothing to another
    /// replica's pool). Requests keep their original `submitted`
    /// instant, so age-ordered admission and deadline enforcement on
    /// the destination are unchanged. A running sequence whose
    /// re-prefill would exceed the prefill window cannot be
    /// reconstructed anywhere — it is finished honestly with `Length`,
    /// exactly as pool-pressure preemption would have.
    pub fn evacuate(&mut self) -> (Vec<Request>, Vec<Running>) {
        let seq_len = self.engine.session.manifest.seq_len;
        let mut fresh = Vec::new();
        let mut resumes = Vec::new();
        let mut slots: Vec<usize> = self.running.keys().copied().collect();
        slots.sort_unstable();
        for slot in slots {
            let run = self.running.remove(&slot).unwrap();
            // no donation: this pool's prefix cache dies with the
            // replica, so there are no holds to track across the move
            self.engine.kv.free(slot);
            if run.request.prompt.len() + run.generated.len() <= seq_len {
                resumes.push(run);
            } else {
                let resp = run.into_response(FinishReason::Length);
                self.metrics.record_finished(&resp);
                self.finished.push(resp);
            }
        }
        // mid-chunked-prefill sequences: the partial prefix dies with
        // this pool, so they restart as fresh requests (or ordinary
        // resumes) on the destination replica
        let pending = std::mem::take(&mut self.prefilling);
        for mut p in pending {
            self.engine.kv.free(p.run.slot);
            if p.run.generated.is_empty() {
                fresh.push(p.run.request);
            } else {
                self.engine.kv.drop_cached(&p.run.donated);
                p.run.donated.clear();
                resumes.push(p.run);
            }
        }
        while let Some(next) = self.batcher.pop_next() {
            match next {
                Admit::New(req) => fresh.push(req),
                Admit::Resume(mut run) => {
                    self.engine.kv.drop_cached(&run.donated);
                    run.donated.clear();
                    resumes.push(run);
                }
            }
        }
        (fresh, resumes)
    }

    /// Run until the queue and all slots drain; returns all responses.
    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Drain the per-token stream events accumulated since the last call.
    pub fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        std::mem::take(&mut self.token_events)
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Cancel one request (client disconnect): drops it from the waiting
    /// queue (fresh or preempted), or frees its KV lane if running.
    /// Returns true if the request was found anywhere.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(req) = self.batcher.remove(id) {
            self.metrics.record_cancelled();
            self.finished.push(Response::cancelled(req.id, req.echo_text));
            return true;
        }
        if let Some(run) = self.batcher.remove_resume(id) {
            // a preempted sequence's only remaining pool footprint is
            // the blocks it donated to the prefix cache — drop exactly
            // those holds (sharers, if any, keep the blocks alive)
            self.engine.kv.drop_cached(&run.donated);
            self.metrics.record_cancelled();
            self.finished.push(run.into_response(FinishReason::Cancelled));
            return true;
        }
        if let Some(pos) =
            self.prefilling.iter().position(|p| p.run.request.id == id)
        {
            let p = self.prefilling.remove(pos).unwrap();
            self.engine.kv.free(p.run.slot);
            self.engine.kv.drop_cached(&p.run.donated);
            self.metrics.record_cancelled();
            self.finished.push(p.run.into_response(FinishReason::Cancelled));
            return true;
        }
        let slot = self
            .running
            .iter()
            .find(|(_, run)| run.request.id == id)
            .map(|(&slot, _)| slot);
        match slot {
            Some(slot) => {
                let run = self.running.remove(&slot).unwrap();
                self.engine.kv.free(slot);
                self.metrics.record_cancelled();
                self.finished.push(run.into_response(FinishReason::Cancelled));
                true
            }
            None => false,
        }
    }

    /// Cancel everything in flight (server shutdown).
    pub fn cancel_all(&mut self) {
        let slots: Vec<usize> = self.running.keys().copied().collect();
        for slot in slots {
            let run = self.running.remove(&slot).unwrap();
            self.engine.kv.free(slot);
            self.metrics.record_cancelled();
            self.finished.push(run.into_response(FinishReason::Cancelled));
        }
        let pending = std::mem::take(&mut self.prefilling);
        for p in pending {
            self.engine.kv.free(p.run.slot);
            self.engine.kv.drop_cached(&p.run.donated);
            self.metrics.record_cancelled();
            self.finished.push(p.run.into_response(FinishReason::Cancelled));
        }
        while let Some(next) = self.batcher.pop_next() {
            self.metrics.record_cancelled();
            match next {
                Admit::New(req) => {
                    self.finished.push(Response::cancelled(req.id, req.echo_text));
                }
                Admit::Resume(run) => {
                    self.engine.kv.drop_cached(&run.donated);
                    self.finished.push(run.into_response(FinishReason::Cancelled));
                }
            }
        }
    }
}
