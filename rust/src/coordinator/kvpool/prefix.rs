//! Content-keyed prefix cache over full KV blocks (vLLM-style automatic
//! prefix caching, the PrefixQuant/IntactKV "pivot KV is a cached
//! artifact" idea made structural).
//!
//! Each *full* prompt block is keyed by a chained hash of everything
//! that determines its contents inside one `PagedKv` lifetime: the
//! block index and the token ids it covers, chained through the hash of
//! the previous block (so a hit at block i implies the entire prompt
//! head up to i matched). The cushion itself never enters the index —
//! it lives in the pinned shared run — but the cushion/token boundary
//! block participates once a prompt fills it.
//!
//! The index holds one pool reference per entry. An indexed block whose
//! only reference is the index's (no live sequence) is *evictable*:
//! allocation falls back to evicting the least-recently-used such block
//! before giving up, so repeated prompts (router demos, eval sweeps,
//! chat system prompts) keep their KV warm exactly as long as the pool
//! has slack.

use std::collections::HashMap;

use super::block::{BlockId, BlockPool};

/// FNV-1a over (previous chain hash, block index, covered token ids).
pub fn chain_hash(prev: u64, block_index: usize, tokens: &[i32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut absorb = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    absorb(&prev.to_le_bytes());
    absorb(&(block_index as u64).to_le_bytes());
    for &t in tokens {
        absorb(&t.to_le_bytes());
    }
    h
}

#[derive(Debug, Default)]
pub struct PrefixIndex {
    by_hash: HashMap<u64, BlockId>,
    by_block: HashMap<BlockId, u64>,
    /// hash -> last-touch tick (LRU eviction order).
    touched: HashMap<u64, u64>,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.by_hash.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_hash.is_empty()
    }

    pub fn contains_block(&self, id: BlockId) -> bool {
        self.by_block.contains_key(&id)
    }

    /// Look a chain hash up without touching LRU state (admission math).
    pub fn peek(&self, hash: u64) -> Option<BlockId> {
        self.by_hash.get(&hash).copied()
    }

    /// Look up and mark as recently used.
    pub fn get(&mut self, hash: u64, tick: u64) -> Option<BlockId> {
        let id = self.by_hash.get(&hash).copied()?;
        self.touched.insert(hash, tick);
        Some(id)
    }

    /// Index `id` under `hash`. Returns true if a new entry was created
    /// — the caller must then `retain` the block on the index's behalf.
    /// An already-present hash (two identical prompts prefilled before
    /// either published) keeps the existing block.
    pub fn insert(&mut self, hash: u64, id: BlockId, tick: u64) -> bool {
        if self.by_hash.contains_key(&hash) || self.by_block.contains_key(&id) {
            return false;
        }
        self.by_hash.insert(hash, id);
        self.by_block.insert(id, hash);
        self.touched.insert(hash, tick);
        true
    }

    /// Blocks whose only reference is the index's — reclaimable without
    /// touching any live sequence.
    pub fn evictable_count(&self, pool: &BlockPool) -> usize {
        self.by_hash
            .values()
            .filter(|&&id| pool.ref_count(id) == 1)
            .count()
    }

    /// Evict the least-recently-used cached block with no live sequence
    /// holder, releasing it back to the pool's free list.
    pub fn evict_lru(&mut self, pool: &mut BlockPool) -> Option<BlockId> {
        let (&hash, _) = self
            .by_hash
            .iter()
            .filter(|(_, &id)| pool.ref_count(id) == 1)
            .min_by_key(|(h, _)| self.touched.get(h).copied().unwrap_or(0))?;
        let id = self.by_hash.remove(&hash)?;
        self.by_block.remove(&id);
        self.touched.remove(&hash);
        let freed = pool.release(id).expect("prefix-cache hold vanished");
        debug_assert!(freed, "evicted a block with live holders");
        Some(id)
    }

    /// Drop one entry by hash, releasing the index's hold on its block
    /// (cancellation of a preempted sequence returns the blocks it
    /// donated). Unlike `evict_lru` this must tolerate live holders:
    /// the block may still back another running sequence.
    pub fn remove(&mut self, hash: u64, pool: &mut BlockPool) -> Option<BlockId> {
        let id = self.by_hash.remove(&hash)?;
        self.by_block.remove(&id);
        self.touched.remove(&hash);
        pool.release(id).expect("prefix-cache hold vanished");
        Some(id)
    }

    /// Drop every entry, releasing the index's holds (pool teardown /
    /// cushion change).
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for (_, id) in self.by_hash.drain() {
            pool.release(id).expect("prefix-cache hold vanished");
        }
        self.by_block.clear();
        self.touched.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kvpool::block::BlockDims;

    fn pool(n: usize) -> BlockPool {
        BlockPool::new(
            n,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 2 },
        )
    }

    #[test]
    fn chain_hash_separates_prefixes() {
        let a = chain_hash(0, 0, &[1, 2, 3]);
        let b = chain_hash(0, 0, &[1, 2, 4]);
        let c = chain_hash(0, 1, &[1, 2, 3]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // chaining: same block tokens under different parents differ
        assert_ne!(chain_hash(a, 1, &[9]), chain_hash(b, 1, &[9]));
        // deterministic
        assert_eq!(a, chain_hash(0, 0, &[1, 2, 3]));
    }

    #[test]
    fn insert_get_evict_roundtrip() {
        let mut p = pool(2);
        let mut ix = PrefixIndex::new();
        let id = p.alloc().unwrap();
        let h = chain_hash(0, 0, &[5, 6]);
        assert!(ix.insert(h, id, 1));
        p.retain(id); // the index's hold
        assert!(!ix.insert(h, id, 2), "duplicate insert keeps the entry");
        assert_eq!(ix.get(h, 3), Some(id));

        // a live holder blocks eviction
        assert_eq!(ix.evictable_count(&p), 0);
        assert!(p.release(id).is_ok()); // sequence drops its ref
        assert_eq!(ix.evictable_count(&p), 1);
        assert_eq!(ix.evict_lru(&mut p), Some(id));
        assert_eq!(p.free_blocks(), 2);
        assert!(ix.is_empty());
    }

    #[test]
    fn evict_lru_picks_oldest_touch() {
        let mut p = pool(3);
        let mut ix = PrefixIndex::new();
        let (a, b) = (p.alloc().unwrap(), p.alloc().unwrap());
        ix.insert(10, a, 1);
        p.retain(a);
        ix.insert(20, b, 2);
        p.retain(b);
        p.release(a).unwrap();
        p.release(b).unwrap();
        ix.get(10, 5); // refresh a
        assert_eq!(ix.evict_lru(&mut p), Some(b), "b is least recently used");
    }
}
