//! `PagedKv` — the paged replacement for the old per-slot `KvManager`.
//!
//! Two resources are managed separately:
//!
//! * **Lanes** (`n_slots`, the graphs' fixed batch width) — an
//!   *execution* resource: which batch row a running sequence occupies.
//! * **Blocks** (the pool) — the *memory* resource: `block_size`-token
//!   KV pages, refcounted and shared.
//!
//! Every sequence's logical KV space is `[0, m_max)` cushion prefix +
//! `[m_max, m_max + tok_len)` request tokens, mapped to physical blocks
//! by its block table. The cushion KV is written **once** into a pinned
//! shared run of blocks that every table starts with — the old
//! `initial_cache` broadcast (one cushion copy per slot) is gone; per-
//! batch execution views are materialized from the pool on demand
//! (kvpool::view). When `m_max` is not a block multiple, the boundary
//! block (cushion tail + first prompt tokens) is a shared template that
//! each sequence copies on write (COW) at allocation.
//!
//! Full prompt blocks are content-keyed into the `PrefixIndex`, so
//! concurrent or repeated prompts with a common head share physical
//! blocks, copy-on-write at the first divergence. Admission is by block
//! availability (`can_admit`), decode growth is block-by-block
//! (`ensure_append`), and when the pool runs dry the scheduler preempts
//! (see coordinator::scheduler) rather than rejecting.
//!
//! Block *contents* are authoritative in the mirrored execution modes
//! (host-roundtrip arena and the native paged path); in the default
//! device-resident arena mode the pool carries the cushion contents plus
//! pure accounting, and the device arena holds the live KV.

use crate::model::manifest::Manifest;
use crate::util::tensor::Tensor;

use super::block::{BlockDims, BlockId, BlockPool};
use super::prefix::{chain_hash, PrefixIndex};

/// One sequence's logical->physical mapping plus sharing bookkeeping.
#[derive(Debug)]
pub struct SeqKv {
    pub request: u64,
    /// Request tokens cached past the cushion region.
    pub tok_len: usize,
    /// Block table: logical block index -> pool block id.
    pub blocks: Vec<BlockId>,
    /// Chained content hash per block (Some only for full prompt
    /// blocks — the publishable prefix-cache keys).
    pub(super) hashes: Vec<Option<u64>>,
    /// Shared blocks (cushion run / prefix-cache hits) are never
    /// written by this sequence; writers COW first.
    pub(super) shared: Vec<bool>,
}

/// Pool occupancy gauges for coordinator::metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub total: usize,
    pub in_use: usize,
    /// Blocks referenced by >= 2 live sequences.
    pub shared: usize,
    /// Block allocations avoided by sharing: sum over blocks of
    /// (sequence holders - 1).
    pub saved: usize,
}

#[derive(Debug)]
pub struct PagedKv {
    pub n_slots: usize,
    pub m_max: usize,
    pub cap: usize,
    pub cushion_len: usize,
    pub block_size: usize,
    pool: BlockPool,
    index: PrefixIndex,
    seqs: Vec<Option<SeqKv>>,
    /// Pinned shared blocks covering positions [0, m_max): the full
    /// cushion run plus (when m_max % block_size != 0) the boundary
    /// template.
    cushion_blocks: Vec<BlockId>,
    /// How many of `cushion_blocks` are fully inside the cushion region
    /// (shareable as-is, never COW'd).
    full_cushion_blocks: usize,
    tick: u64,
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

impl PagedKv {
    /// Geometry from the manifest: `kv_block_size` / `kv_pool_blocks`
    /// when set (non-zero), otherwise a block size of min(16, m_max)
    /// tokens and a pool sized so every lane can reach `cache_cap` with
    /// the cushion run shared — the no-preemption parity configuration.
    pub fn for_manifest(
        m: &Manifest,
        cushion_kv: Option<&Tensor>,
        cushion_len: usize,
        pool_blocks_override: Option<usize>,
    ) -> Self {
        let cap = m.cache_cap.max(1);
        let bs = if m.kv_block_size > 0 {
            m.kv_block_size
        } else if m.m_max > 0 {
            16.min(m.m_max)
        } else {
            16
        }
        .min(cap);
        let full = m.m_max / bs;
        let boundary = usize::from(m.m_max % bs != 0);
        let per_lane = ceil_div(cap, bs) - full;
        let derived = full + boundary + m.serve_batch * per_lane;
        let n_blocks = pool_blocks_override
            .or((m.kv_pool_blocks > 0).then_some(m.kv_pool_blocks))
            .unwrap_or(derived);
        Self::new(
            m.serve_batch,
            m.m_max,
            m.cache_cap,
            cushion_len,
            bs,
            n_blocks,
            BlockDims {
                n_layers: m.n_layers,
                n_kv_heads: m.n_kv_heads,
                d_head: m.d_head,
                block_size: bs,
            },
            cushion_kv,
        )
    }

    /// `cushion_kv`: `[L, 2, Hkv, m_max, dh]` (None = zero prefix KV,
    /// matching the old zero-initialized cache).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_slots: usize,
        m_max: usize,
        cap: usize,
        cushion_len: usize,
        block_size: usize,
        n_blocks: usize,
        dims: BlockDims,
        cushion_kv: Option<&Tensor>,
    ) -> Self {
        assert!(cushion_len <= m_max, "cushion longer than the prefix region");
        assert_eq!(dims.block_size, block_size);
        assert!(block_size > 0);
        let n_cushion = ceil_div(m_max, block_size);
        // floor: the cushion run plus one lane's full-capacity table must
        // fit, or a lone max-length sequence could never run (the
        // preemption policy guarantees progress only above this floor)
        let lane_max = ceil_div(cap, block_size) - m_max / block_size;
        let n_blocks = n_blocks.max(n_cushion + lane_max);
        let mut pool = BlockPool::new(n_blocks, dims);
        let mut cushion_blocks = Vec::with_capacity(n_cushion);
        for bi in 0..n_cushion {
            let id = pool.alloc().expect("pool smaller than the cushion run");
            pool.pin(id);
            if let Some(kv) = cushion_kv {
                write_cushion_block(&mut pool, id, bi, m_max, kv);
            }
            cushion_blocks.push(id);
        }
        Self {
            n_slots,
            m_max,
            cap,
            cushion_len,
            block_size,
            pool,
            index: PrefixIndex::new(),
            seqs: (0..n_slots).map(|_| None).collect(),
            full_cushion_blocks: m_max / block_size,
            cushion_blocks,
            tick: 0,
        }
    }

    // -- lane-level surface (the old KvManager API) -----------------------

    pub fn free_count(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_none()).count()
    }

    pub fn busy_slots(&self) -> Vec<usize> {
        (0..self.n_slots).filter(|&s| self.seqs[s].is_some()).collect()
    }

    pub fn request_of(&self, slot: usize) -> Option<u64> {
        self.seqs[slot].as_ref().map(|s| s.request)
    }

    pub fn tok_len(&self, slot: usize) -> usize {
        self.seqs[slot].as_ref().map(|s| s.tok_len).unwrap_or(0)
    }

    /// Room left (in tokens) for this slot's logical sequence.
    pub fn remaining(&self, slot: usize) -> usize {
        self.cap - self.m_max - self.tok_len(slot)
    }

    /// Per-slot token lengths for the decode graphs' cache_tok_len input.
    pub fn lens_i32(&self) -> Vec<i32> {
        (0..self.n_slots).map(|s| self.tok_len(s) as i32).collect()
    }

    /// Allocate a lane + block table for a prompt known only by length
    /// (no prefix caching — compat path for drivers that feed tokens to
    /// `prefill` directly).
    pub fn alloc(&mut self, request: u64, prompt_len: usize) -> Option<usize> {
        self.alloc_seq(request, prompt_len, None)
    }

    /// Allocate with the actual prompt tokens: full prompt-head blocks
    /// are looked up in the prefix cache and shared on a hit.
    pub fn alloc_with_prompt(&mut self, request: u64, prompt: &[i32]) -> Option<usize> {
        self.alloc_seq(request, prompt.len(), Some(prompt))
    }

    fn alloc_seq(
        &mut self,
        request: u64,
        prompt_len: usize,
        prompt: Option<&[i32]>,
    ) -> Option<usize> {
        if self.m_max + prompt_len > self.cap {
            return None; // can never fit
        }
        let slot = self.seqs.iter().position(Option::is_none)?;
        self.tick += 1;
        let n_needed = ceil_div(self.m_max + prompt_len, self.block_size);
        let mut blocks: Vec<BlockId> = Vec::with_capacity(n_needed);
        let mut hashes: Vec<Option<u64>> = Vec::with_capacity(n_needed);
        let mut shared: Vec<bool> = Vec::with_capacity(n_needed);

        // the shared cushion run heads every table
        for bi in 0..self.cushion_blocks.len().min(n_needed) {
            let id = self.cushion_blocks[bi];
            self.pool.retain(id);
            blocks.push(id);
            hashes.push(None);
            shared.push(true);
        }

        // token-bearing blocks: boundary template -> COW, full blocks ->
        // prefix-cache lookup, the rest -> fresh
        let mut prev = 0u64;
        let mut ok = true;
        for bi in self.full_cushion_blocks..n_needed {
            let span_lo = (bi * self.block_size).max(self.m_max);
            let span_hi = ((bi + 1) * self.block_size).min(self.m_max + prompt_len);
            let is_full = (bi + 1) * self.block_size <= self.m_max + prompt_len;
            let hash = match (prompt, is_full) {
                (Some(p), true) => {
                    let toks = &p[span_lo - self.m_max..span_hi - self.m_max];
                    prev = chain_hash(prev, bi, toks);
                    Some(prev)
                }
                _ => None,
            };
            if let Some(h) = hash {
                if let Some(id) = self.index.get(h, self.tick) {
                    // prefix-cache hit: share, never write
                    self.pool.retain(id);
                    if bi < blocks.len() {
                        // the boundary slot was pre-filled with the
                        // template; the cached block replaces it
                        let old = blocks[bi];
                        self.pool.release(old).expect("cushion run hold");
                        blocks[bi] = id;
                        hashes[bi] = Some(h);
                        // stays shared == true
                    } else {
                        blocks.push(id);
                        hashes.push(Some(h));
                        shared.push(true);
                    }
                    continue;
                }
            }
            // miss: this sequence owns (and will write) the block
            let Some(id) = self.alloc_block() else {
                ok = false;
                break;
            };
            if bi < blocks.len() {
                // boundary template COW: carry the cushion tail over
                let template = blocks[bi];
                self.pool.copy_block(template, id);
                self.pool.release(template).expect("cushion run hold");
                blocks[bi] = id;
                hashes[bi] = hash;
                shared[bi] = false;
            } else {
                blocks.push(id);
                hashes.push(hash);
                shared.push(false);
            }
        }
        if !ok {
            for id in blocks {
                self.pool.release(id).expect("rollback release");
            }
            return None;
        }
        let prefix_hits = hashes
            .iter()
            .zip(&shared)
            .filter(|(h, &s)| h.is_some() && s)
            .count();
        crate::runtime::trace::instant("kv_alloc", "kvpool", Some(request), &[
            ("slot", slot.to_string()),
            ("blocks", blocks.len().to_string()),
            ("prefix_hits", prefix_hits.to_string()),
        ]);
        self.seqs[slot] = Some(SeqKv {
            request,
            tok_len: prompt_len,
            blocks,
            hashes,
            shared,
        });
        Some(slot)
    }

    /// Free a lane: donate full prompt blocks to the prefix cache, then
    /// drop every table reference (shared blocks survive under their
    /// other holders).
    pub fn free(&mut self, slot: usize) {
        let _ = self.free_donating(slot);
    }

    /// `free`, additionally returning the prefix-cache hashes whose
    /// index entries this sequence accounts for — blocks it donated
    /// right now, plus blocks it published earlier (`publish_prefix`)
    /// that still map to its table. The scheduler stores these on a
    /// preempted `Running` so a cancel-before-resume can release the
    /// donation via `drop_cached` exactly once.
    pub fn free_donating(&mut self, slot: usize) -> Vec<u64> {
        let Some(seq) = self.seqs[slot].take() else { return Vec::new() };
        self.tick += 1;
        let mut donated = Vec::new();
        for (i, &id) in seq.blocks.iter().enumerate() {
            if let Some(h) = seq.hashes[i] {
                if !seq.shared[i] {
                    if self.index.insert(h, id, self.tick) {
                        self.pool.retain(id);
                        donated.push(h);
                    } else if self.index.peek(h) == Some(id) {
                        donated.push(h);
                    }
                }
            }
            self.pool.release(id).expect("table hold vanished");
        }
        donated
    }

    /// Drop specific prefix-cache entries by hash (a cancelled
    /// preempted sequence releases its donations). Returns how many
    /// entries were present and removed — an entry may have been
    /// LRU-evicted in the meantime, in which case eviction already
    /// released the index's hold and this is a no-op for it.
    pub fn drop_cached(&mut self, hashes: &[u64]) -> usize {
        let mut n = 0;
        for &h in hashes {
            if self.index.remove(h, &mut self.pool).is_some() {
                n += 1;
            }
        }
        n
    }

    /// Record one decoded token appended to `slot`. The covering block
    /// must be allocatable; serving flows call `ensure_append` (with
    /// preemption on failure) before the decode step, making this
    /// infallible there.
    pub fn push_token(&mut self, slot: usize) {
        assert!(
            self.ensure_append(slot),
            "kv pool exhausted — ensure_append/preemption must run first"
        );
        let seq = self.seqs[slot].as_mut().expect("push_token on a free lane");
        seq.tok_len += 1;
        debug_assert!(self.m_max + seq.tok_len <= self.cap);
    }

    /// Make sure the block covering this slot's next KV write position
    /// (`m_max + tok_len`) exists and is writable. Returns false when
    /// the pool (free list + evictable prefix cache) is exhausted.
    pub fn ensure_append(&mut self, slot: usize) -> bool {
        let Some(seq) = self.seqs[slot].as_ref() else { return true };
        let pos = self.m_max + seq.tok_len;
        if pos >= self.cap {
            return true; // sequence is at capacity; should_stop owns this
        }
        let bi = pos / self.block_size;
        if bi < seq.blocks.len() {
            if !seq.shared[bi] {
                return true;
            }
            // defensive COW (normal flows COW shared blocks at alloc)
            let Some(fresh) = self.alloc_block() else { return false };
            let seq = self.seqs[slot].as_mut().unwrap();
            let old = seq.blocks[bi];
            seq.blocks[bi] = fresh;
            seq.shared[bi] = false;
            seq.hashes[bi] = None;
            self.pool.copy_block(old, fresh);
            self.pool.release(old).expect("shared hold vanished");
            return true;
        }
        debug_assert_eq!(bi, seq.blocks.len(), "table gap");
        let Some(id) = self.alloc_block() else { return false };
        let seq = self.seqs[slot].as_mut().unwrap();
        seq.blocks.push(id);
        seq.hashes.push(None);
        seq.shared.push(false);
        true
    }

    /// Publish this sequence's full prompt blocks into the prefix cache
    /// (called after a successful prefill, so concurrent identical
    /// prompts share immediately).
    pub fn publish_prefix(&mut self, slot: usize) {
        let Some(seq) = self.seqs[slot].as_ref() else { return };
        let entries: Vec<(u64, BlockId)> = seq
            .blocks
            .iter()
            .enumerate()
            .filter(|&(i, _)| !seq.shared[i])
            .filter_map(|(i, &id)| seq.hashes[i].map(|h| (h, id)))
            .collect();
        self.tick += 1;
        for (h, id) in entries {
            if self.index.insert(h, id, self.tick) {
                self.pool.retain(id);
            }
        }
    }

    // -- admission math ----------------------------------------------------

    /// Can a prompt be admitted *now* on block availability alone?
    /// Requires room for the prompt plus — unless the request finishes
    /// at the cache limit or after its single prefill token — at least
    /// one generated token's KV (the old `alloc` admitted prompts with
    /// zero decode room and relied on overflow asserts downstream).
    pub fn can_admit(&self, prompt: &[i32], max_new: usize) -> bool {
        let plen = prompt.len();
        if self.m_max + plen > self.cap {
            return false;
        }
        let extra = usize::from(max_new > 1 && self.m_max + plen < self.cap);
        let n_needed = ceil_div(self.m_max + plen + extra, self.block_size);
        let mut needed_new = 0usize;
        let mut prev = 0u64;
        for bi in self.full_cushion_blocks..n_needed {
            let is_full = (bi + 1) * self.block_size <= self.m_max + plen;
            if is_full {
                let span_lo = (bi * self.block_size).max(self.m_max);
                let span_hi = (bi + 1) * self.block_size;
                prev = chain_hash(
                    prev,
                    bi,
                    &prompt[span_lo - self.m_max..span_hi - self.m_max],
                );
                if self.index.peek(prev).is_some() {
                    continue; // shared on admission, no new block
                }
            }
            needed_new += 1;
        }
        needed_new <= self.available_blocks()
    }

    /// Blocks obtainable right now: the free list plus prefix-cache
    /// entries with no live sequence holder.
    pub fn available_blocks(&self) -> usize {
        self.pool.free_blocks() + self.index.evictable_count(&self.pool)
    }

    fn alloc_block(&mut self) -> Option<BlockId> {
        if let Some(id) = self.pool.alloc() {
            return Some(id);
        }
        self.index.evict_lru(&mut self.pool)?;
        self.pool.alloc()
    }

    /// Drop every cached-but-idle prefix block (tests / memory pressure
    /// hooks).
    pub fn clear_prefix_cache(&mut self) {
        while self.index.evict_lru(&mut self.pool).is_some() {}
    }

    // -- observability -----------------------------------------------------

    pub fn total_blocks(&self) -> usize {
        self.pool.n_blocks()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.pool.blocks_in_use()
    }

    /// The table of a running sequence (tests / the paged graphs).
    pub fn table(&self, slot: usize) -> Option<&[BlockId]> {
        self.seqs[slot].as_ref().map(|s| s.blocks.as_slice())
    }

    /// The pinned shared cushion run (first `full_cushion_blocks` are
    /// fully shared; a trailing boundary template COWs per sequence).
    pub fn cushion_run(&self) -> &[BlockId] {
        &self.cushion_blocks
    }

    pub fn full_cushion_blocks(&self) -> usize {
        self.full_cushion_blocks
    }

    pub fn prefix_cache_len(&self) -> usize {
        self.index.len()
    }

    pub fn pool_stats(&self) -> PoolStats {
        let mut shared = 0usize;
        let mut saved = 0usize;
        for id in 0..self.pool.n_blocks() {
            let refs = self.pool.ref_count(id);
            if refs == 0 {
                continue;
            }
            let base = u32::from(self.pool.is_pinned(id))
                + u32::from(self.index.contains_block(id));
            let seq_refs = refs.saturating_sub(base);
            if seq_refs >= 2 {
                shared += 1;
            }
            saved += seq_refs.saturating_sub(1) as usize;
        }
        PoolStats {
            total: self.pool.n_blocks(),
            in_use: self.pool.blocks_in_use(),
            shared,
            saved,
        }
    }

    // -- pool plumbing shared with kvpool::view ---------------------------

    pub(super) fn pool_ref(&self) -> &BlockPool {
        &self.pool
    }

    pub(super) fn pool_mut(&mut self) -> &mut BlockPool {
        &mut self.pool
    }

    pub(super) fn seq(&self, slot: usize) -> Option<&SeqKv> {
        self.seqs[slot].as_ref()
    }
}

/// Write the cushion KV rows that fall inside cushion-run block `bi`.
/// kv: [L, 2, Hkv, m_max, dh].
fn write_cushion_block(
    pool: &mut BlockPool,
    id: BlockId,
    bi: usize,
    m_max: usize,
    kv: &Tensor,
) {
    let d = *pool.dims();
    assert_eq!(
        kv.shape,
        vec![d.n_layers, 2, d.n_kv_heads, m_max, d.d_head],
        "cushion KV shape mismatch"
    );
    let (hkv, dh, bs) = (d.n_kv_heads, d.d_head, d.block_size);
    let p0 = bi * bs;
    let p1 = ((bi + 1) * bs).min(m_max);
    let block = pool.block_mut(id);
    for l in 0..d.n_layers {
        for w in 0..2 {
            for h in 0..hkv {
                for p in p0..p1 {
                    let src = (((l * 2 + w) * hkv + h) * m_max + p) * dh;
                    let dst = d.row(l, w, h, p - p0);
                    block[dst..dst + dh].copy_from_slice(&kv.data[src..src + dh]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n_slots: usize, n_blocks: usize) -> PagedKv {
        // m_max 4, cap 20, bs 4: 1 full cushion block, 4 token blocks/lane
        PagedKv::new(
            n_slots,
            4,
            20,
            2,
            4,
            n_blocks,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 4 },
            None,
        )
    }

    #[test]
    fn alloc_free_cycle_lane_semantics() {
        let mut p = kv(2, 9);
        let a = p.alloc(10, 5).unwrap();
        let b = p.alloc(11, 5).unwrap();
        assert_ne!(a, b);
        assert!(p.alloc(12, 5).is_none(), "no free lane");
        p.free(a);
        assert_eq!(p.free_count(), 1);
        let c = p.alloc(12, 5).unwrap();
        assert_eq!(c, a);
        assert_eq!(p.request_of(c), Some(12));
        assert!(p.alloc(13, 17).is_none(), "prompt longer than cap");
    }

    #[test]
    fn capacity_and_growth() {
        let mut p = kv(1, 9);
        let s = p.alloc(1, 4).unwrap();
        assert_eq!(p.remaining(s), 12);
        // cushion block + 1 prompt block
        assert_eq!(p.blocks_in_use(), 2);
        for _ in 0..4 {
            p.push_token(s);
        }
        assert_eq!(p.tok_len(s), 8);
        assert_eq!(p.blocks_in_use(), 3, "decode growth allocates lazily");
        assert_eq!(p.lens_i32(), vec![8]);
        p.free(s);
        assert_eq!(p.blocks_in_use(), 1, "only the pinned cushion remains");
    }

    #[test]
    fn cushion_run_is_shared_across_lanes() {
        let cushion = Tensor::new(
            vec![1, 2, 1, 4, 2],
            (0..16).map(|i| i as f32).collect(),
        );
        let mut p = PagedKv::new(
            2,
            4,
            20,
            4,
            4,
            9,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 4 },
            Some(&cushion),
        );
        let a = p.alloc(1, 6).unwrap();
        let b = p.alloc(2, 6).unwrap();
        let (ta, tb) = (p.table(a).unwrap().to_vec(), p.table(b).unwrap().to_vec());
        assert_eq!(ta[0], tb[0], "cushion block shared, not copied");
        assert_eq!(p.cushion_run(), &[ta[0]]);
        // cushion KV landed in the shared block (K of layer 0, pos 0)
        assert_eq!(p.pool_ref().block(ta[0])[0], 0.0);
        assert_eq!(p.pool_ref().block(ta[0])[2], 2.0, "pos 1 row");
        let stats = p.pool_stats();
        // 1 cushion + 2 * 2 prompt blocks, cushion counted once
        assert_eq!(stats.in_use, 5);
        assert_eq!(stats.shared, 1);
        assert_eq!(stats.saved, 1);
    }

    #[test]
    fn prefix_cache_shares_full_prompt_blocks() {
        let mut p = kv(2, 9);
        let prompt = vec![7, 8, 9, 10, 11, 12]; // 1.5 token blocks
        let a = p.alloc_with_prompt(1, &prompt).unwrap();
        p.publish_prefix(a);
        assert_eq!(p.prefix_cache_len(), 1, "one full prompt block published");
        let b = p.alloc_with_prompt(2, &prompt).unwrap();
        let (ta, tb) = (p.table(a).unwrap().to_vec(), p.table(b).unwrap().to_vec());
        assert_eq!(ta[1], tb[1], "full prompt block shared via the index");
        assert_ne!(ta[2], tb[2], "partial tail block is private");
        // divergent prompt shares nothing
        p.free(b);
        let c = p.alloc_with_prompt(3, &[7, 8, 9, 99, 11, 12]).unwrap();
        assert_ne!(ta[1], p.table(c).unwrap()[1]);
    }

    #[test]
    fn freed_prompt_blocks_stay_cached_and_evict_lru() {
        let mut p = kv(1, 4); // cushion + 3 spare blocks
        let a = p.alloc_with_prompt(1, &[1, 2, 3, 4, 5]).unwrap();
        p.publish_prefix(a);
        p.free(a);
        assert_eq!(p.prefix_cache_len(), 1);
        assert_eq!(p.blocks_in_use(), 2, "cached block survives the free");
        // same prompt resumes on the cached block
        let b = p.alloc_with_prompt(2, &[1, 2, 3, 4, 5]).unwrap();
        assert!(p.table(b).unwrap().contains(&p.cushion_run()[0]));
        p.free(b);
        // pressure evicts the cached block once nothing references it
        let c = p.alloc_with_prompt(3, &[9; 13]).unwrap(); // needs 4 blocks total
        assert_eq!(p.prefix_cache_len(), 0, "LRU cache evicted under pressure");
        p.free(c);
        p.clear_prefix_cache();
        assert_eq!(p.blocks_in_use(), 1, "full churn returns to the cushion");
    }

    #[test]
    fn free_donating_reports_and_drop_cached_releases_exactly_once() {
        let mut p = kv(2, 9);
        let prompt = vec![7, 8, 9, 10, 11]; // 1 full token block + tail
        let a = p.alloc_with_prompt(1, &prompt).unwrap();
        let donated = p.free_donating(a);
        assert_eq!(donated.len(), 1, "one full prompt block donated");
        assert_eq!(p.prefix_cache_len(), 1);
        let in_use = p.blocks_in_use();
        assert_eq!(p.drop_cached(&donated), 1);
        assert_eq!(p.prefix_cache_len(), 0);
        assert_eq!(p.blocks_in_use(), in_use - 1, "donation hold released");
        assert_eq!(p.drop_cached(&donated), 0, "second drop is a no-op");
        assert_eq!(p.blocks_in_use(), 1, "back to the pinned cushion");

        // a block published during the run (publish_prefix) is still
        // reported as this sequence's donation at free time
        let b = p.alloc_with_prompt(2, &prompt).unwrap();
        p.publish_prefix(b);
        assert_eq!(p.prefix_cache_len(), 1);
        let donated = p.free_donating(b);
        assert_eq!(donated.len(), 1);
        // a live sharer must survive the donor's drop
        let c = p.alloc_with_prompt(3, &prompt).unwrap();
        assert_eq!(p.drop_cached(&donated), 1);
        assert!(p.table(c).is_some());
        p.free(c);
        p.clear_prefix_cache();
        assert_eq!(p.blocks_in_use(), 1, "no leaked holds after churn");
    }

    #[test]
    fn boundary_template_cows_per_sequence() {
        // m_max 2, bs 4: the cushion tail lives in a boundary template
        let cushion =
            Tensor::new(vec![1, 2, 1, 2, 2], (0..8).map(|i| i as f32 + 1.0).collect());
        let mut p = PagedKv::new(
            2,
            2,
            10,
            2,
            4,
            8,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 4 },
            Some(&cushion),
        );
        assert_eq!(p.full_cushion_blocks(), 0);
        assert_eq!(p.cushion_run().len(), 1);
        let template = p.cushion_run()[0];
        let a = p.alloc(1, 3).unwrap();
        let owned = p.table(a).unwrap()[0];
        assert_ne!(owned, template, "boundary block COWs at alloc");
        // the cushion tail was carried over; the template is untouched
        assert_eq!(p.pool_ref().block(owned)[..4], p.pool_ref().block(template)[..4]);
        p.pool_mut().block_mut(owned)[0] = -1.0;
        assert_eq!(p.pool_ref().block(template)[0], 1.0, "COW preserved source");
    }

    #[test]
    fn can_admit_requires_decode_room() {
        let p = kv(1, 9);
        assert!(p.can_admit(&[1; 16], 8), "full-cap prompt finishes with Length");
        assert!(!p.can_admit(&[1; 17], 8), "over cap");
        assert!(p.can_admit(&[1; 15], 8), "room for one generated token");
        let tiny = kv(1, 2); // floored to cushion + one lane's need = 5
        assert_eq!(tiny.total_blocks(), 5);
    }

    #[test]
    fn exhausted_pool_fails_ensure_append() {
        // cushion + exactly 2 token blocks
        let mut p = PagedKv::new(
            2,
            4,
            20,
            0,
            4,
            3,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 4 },
            None,
        );
        // floor keeps one lane viable: 1 cushion + 4 token blocks... but we
        // asked for 3 and the floor is 5
        assert_eq!(p.total_blocks(), 5);
        let a = p.alloc(1, 8).unwrap(); // 2 token blocks
        let b = p.alloc(2, 8).unwrap(); // 2 token blocks -> pool full
        assert_eq!(p.blocks_in_use(), 5);
        assert!(!p.ensure_append(a), "no block for growth");
        p.free(b);
        assert!(p.ensure_append(a), "freed blocks enable growth");
    }
}
