//! The physical block pool: `n_blocks` fixed-size KV blocks, each
//! holding `block_size` token positions of every layer's K and V —
//! layout `[L, 2, Hkv, BS, dh]` per block, all blocks in one contiguous
//! `data` buffer (so the whole pool can cross to a graph as one tensor).
//!
//! Blocks are refcounted: a sequence's block table holds one reference
//! per entry, the prefix cache holds one per indexed block, and the
//! pinned cushion run holds one per block forever. `release` is checked
//! — a refcount can never underflow, and a pinned block can never reach
//! zero — so double-free bugs surface as `Err`, not corruption
//! (testkit::prop churn properties pin this down).

use crate::util::tensor::Tensor;

pub type BlockId = usize;

/// Per-block tensor geometry (shared by every block in a pool).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDims {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub block_size: usize,
}

impl BlockDims {
    /// f32 elements per block: L * 2 * Hkv * BS * dh.
    pub fn block_elems(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.block_size * self.d_head
    }

    /// Offset of one dh-row inside a block: (layer, k-or-v, head,
    /// position-in-block).
    pub fn row(&self, l: usize, which: usize, h: usize, q: usize) -> usize {
        (((l * 2 + which) * self.n_kv_heads + h) * self.block_size + q)
            * self.d_head
    }
}

#[derive(Debug)]
pub struct BlockPool {
    dims: BlockDims,
    n_blocks: usize,
    data: Vec<f32>,
    refs: Vec<u32>,
    pinned: Vec<bool>,
    free: Vec<BlockId>,
}

impl BlockPool {
    pub fn new(n_blocks: usize, dims: BlockDims) -> Self {
        Self {
            data: vec![0.0; n_blocks * dims.block_elems()],
            refs: vec![0; n_blocks],
            pinned: vec![false; n_blocks],
            free: (0..n_blocks).rev().collect(),
            n_blocks,
            dims,
        }
    }

    pub fn dims(&self) -> &BlockDims {
        &self.dims
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Take a free block (refcount 1, contents zeroed). `None` = pool
    /// exhausted; the caller decides between eviction and preemption.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refs[id], 0, "free-list block with live refs");
        self.refs[id] = 1;
        let e = self.dims.block_elems();
        self.data[id * e..(id + 1) * e].fill(0.0);
        Some(id)
    }

    /// Add one reference (table entry / prefix-cache hold / pin hold).
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refs[id] > 0, "retain of unallocated block {id}");
        self.refs[id] += 1;
    }

    /// Drop one reference; returns whether the block became free. A
    /// refcount underflow (releasing an already-free block) and a pinned
    /// block reaching zero are both hard errors.
    pub fn release(&mut self, id: BlockId) -> crate::Result<bool> {
        anyhow::ensure!(id < self.n_blocks, "release of block {id} out of range");
        anyhow::ensure!(self.refs[id] > 0, "refcount underflow on block {id}");
        anyhow::ensure!(
            self.refs[id] > 1 || !self.pinned[id],
            "pinned block {id} would be freed"
        );
        self.refs[id] -= 1;
        if self.refs[id] == 0 {
            self.free.push(id);
            return Ok(true);
        }
        Ok(false)
    }

    /// Mark a block as pinned (the cushion run): its holder's reference
    /// is permanent and `release` refuses to free it.
    pub fn pin(&mut self, id: BlockId) {
        assert!(self.refs[id] > 0, "pin of unallocated block {id}");
        self.pinned[id] = true;
    }

    pub fn is_pinned(&self, id: BlockId) -> bool {
        self.pinned[id]
    }

    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.refs[id]
    }

    pub fn block(&self, id: BlockId) -> &[f32] {
        let e = self.dims.block_elems();
        &self.data[id * e..(id + 1) * e]
    }

    pub fn block_mut(&mut self, id: BlockId) -> &mut [f32] {
        let e = self.dims.block_elems();
        &mut self.data[id * e..(id + 1) * e]
    }

    /// Copy-on-write substrate: duplicate `src`'s contents into `dst`.
    pub fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        let e = self.dims.block_elems();
        let (s0, d0) = (src * e, dst * e);
        if src == dst {
            return;
        }
        // split_at_mut needs ordered disjoint ranges
        let (lo, hi, src_first) = if s0 < d0 {
            (s0, d0, true)
        } else {
            (d0, s0, false)
        };
        let (a, b) = self.data.split_at_mut(hi);
        if src_first {
            b[..e].copy_from_slice(&a[lo..lo + e]);
        } else {
            a[lo..lo + e].copy_from_slice(&b[..e]);
        }
    }

    /// The whole pool as one flat slice (paged-graph operand).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Replace the whole pool contents (installing a paged graph's
    /// functional output). Length must match exactly.
    pub fn install_data(&mut self, data: &[f32]) -> crate::Result<()> {
        anyhow::ensure!(
            data.len() == self.data.len(),
            "pool install: {} elements, expected {}",
            data.len(),
            self.data.len()
        );
        self.data.copy_from_slice(data);
        Ok(())
    }

    /// The pool as a `[n_blocks, L, 2, Hkv, BS, dh]` tensor (clones the
    /// data — the paged-graph operand path).
    pub fn as_tensor(&self) -> Tensor {
        let d = &self.dims;
        Tensor::new(
            vec![self.n_blocks, d.n_layers, 2, d.n_kv_heads, d.block_size,
                 d.d_head],
            self.data.clone(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> BlockDims {
        BlockDims { n_layers: 2, n_kv_heads: 1, d_head: 3, block_size: 4 }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = BlockPool::new(3, dims());
        assert_eq!(p.free_blocks(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.blocks_in_use(), 2);
        assert!(p.release(a).unwrap());
        assert_eq!(p.free_blocks(), 2);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "LIFO free list reuses the freed block");
        assert_eq!(p.ref_count(b), 1);
    }

    #[test]
    fn refcounts_are_checked() {
        let mut p = BlockPool::new(2, dims());
        let a = p.alloc().unwrap();
        p.retain(a);
        assert!(!p.release(a).unwrap(), "still one holder");
        assert!(p.release(a).unwrap());
        assert!(p.release(a).is_err(), "underflow must error");
        let b = p.alloc().unwrap();
        p.pin(b);
        assert!(p.release(b).is_err(), "pinned block cannot be freed");
    }

    #[test]
    fn alloc_zeroes_and_copy_preserves_source() {
        let mut p = BlockPool::new(2, dims());
        let a = p.alloc().unwrap();
        p.block_mut(a).iter_mut().for_each(|v| *v = 7.0);
        assert!(p.release(a).unwrap());
        let a2 = p.alloc().unwrap();
        assert!(p.block(a2).iter().all(|&v| v == 0.0), "stale data leaked");
        p.block_mut(a2)[0] = 3.0;
        let b = p.alloc().unwrap();
        p.copy_block(a2, b);
        assert_eq!(p.block(b)[0], 3.0);
        p.block_mut(b)[0] = 9.0;
        assert_eq!(p.block(a2)[0], 3.0, "COW copy must not alias the source");
    }
}
