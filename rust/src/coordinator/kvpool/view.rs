//! Execution views over the paged pool.
//!
//! The compiled/interpreted `prefill_*`/`decode_*` graphs consume one
//! contiguous cache tensor `[L, 2, B, Hkv, CAP, dh]`. The pool stores
//! KV once per *block*; this module bridges the two:
//!
//! * `gather_view` / `lane_view` — materialize the per-batch contiguous
//!   view from block tables (engine init, parity cross-checks). Lanes
//!   with no sequence show the shared cushion run. This is the only
//!   place a cushion "broadcast" ever appears, and it is a transient
//!   execution view — storage stays single-copy in the pool.
//! * `scatter_prefill` / `scatter_decode_row` — mirror the positions a
//!   graph just wrote in the contiguous view back into the owning
//!   sequence's blocks (host-resident modes). Shared blocks are never
//!   written: prefix-cache hits already hold identical contents by
//!   construction (same tokens, same weights, same graph).
//! * `tables_tensor` / `table_i32` / `pool_tensor` / `install_pool` —
//!   operand plumbing for the *native* block-table graphs
//!   (`prefill_paged_*` / `decode_paged_*`, runtime::interp), which
//!   skip the contiguous view entirely.
//! * `cache_with_cushion` — the standalone view builder (golden-fixture
//!   tests build graph inputs without a pool).

use crate::runtime::literalx::IntTensor;
use crate::util::tensor::Tensor;

use super::paged::PagedKv;

/// Build a `[L, 2, B, Hkv, CAP, dh]` cache with the cushion KV
/// replicated into every lane's prefix region — the execution-view
/// equivalent of the pre-paging `initial_cache`, used where graph
/// operands are assembled without a pool (fixture tests).
pub fn cache_with_cushion(
    n_layers: usize,
    n_kv_heads: usize,
    d_head: usize,
    n_slots: usize,
    cap: usize,
    m_max: usize,
    cushion_kv: Option<&Tensor>,
) -> Tensor {
    let mut cache =
        Tensor::zeros(&[n_layers, 2, n_slots, n_kv_heads, cap, d_head]);
    if let Some(kv) = cushion_kv {
        assert_eq!(
            kv.shape,
            vec![n_layers, 2, n_kv_heads, m_max, d_head],
            "cushion KV shape mismatch"
        );
        let src_block = m_max * d_head;
        let dst_row = cap * d_head;
        for l in 0..n_layers {
            for w in 0..2 {
                for h in 0..n_kv_heads {
                    let s0 = ((l * 2 + w) * n_kv_heads + h) * src_block;
                    let src = &kv.data[s0..s0 + src_block];
                    for b in 0..n_slots {
                        let d0 = (((l * 2 + w) * n_slots + b) * n_kv_heads + h)
                            * dst_row;
                        cache.data[d0..d0 + src_block].copy_from_slice(src);
                    }
                }
            }
        }
    }
    cache
}

impl PagedKv {
    fn geometry(&self) -> (usize, usize, usize, usize) {
        let d = self.pool_ref().dims();
        (d.n_layers, d.n_kv_heads, d.d_head, d.block_size)
    }

    /// Gather the full per-batch execution view from block tables.
    pub fn gather_view(&self) -> Tensor {
        let (nl, hkv, dh, _) = self.geometry();
        let mut cache =
            Tensor::zeros(&[nl, 2, self.n_slots, hkv, self.cap, dh]);
        for slot in 0..self.n_slots {
            self.gather_into(&mut cache.data, slot);
        }
        cache
    }

    /// One shard's `[L, 2, B, Hkv/n, CAP, dh]` execution view: the same
    /// gather as `gather_view` restricted to the shard's KV heads
    /// (tensor-parallel serving). Block tables stay global — one
    /// allocation, one prefix cache, one cushion run — only the head
    /// axis of the materialized storage is per-shard.
    pub fn gather_view_shard(
        &self,
        shard: usize,
        n_shards: usize,
    ) -> crate::Result<Tensor> {
        let (nl, hkv, dh, _) = self.geometry();
        anyhow::ensure!(
            n_shards >= 1 && shard < n_shards && hkv % n_shards == 0,
            "kvpool: shard {shard}/{n_shards} invalid for {hkv} KV heads"
        );
        let loc = hkv / n_shards;
        let h0 = shard * loc;
        let full = self.gather_view();
        let mut out = Tensor::zeros(&[nl, 2, self.n_slots, loc, self.cap, dh]);
        let row = self.cap * dh;
        for lw in 0..nl * 2 {
            for b in 0..self.n_slots {
                for h in 0..loc {
                    let src = ((lw * self.n_slots + b) * hkv + h0 + h) * row;
                    let dst = ((lw * self.n_slots + b) * loc + h) * row;
                    out.data[dst..dst + row]
                        .copy_from_slice(&full.data[src..src + row]);
                }
            }
        }
        Ok(out)
    }

    /// Mirror a sharded prefill's written positions back into the owned
    /// blocks — `scatter_prefill` for a shard-local cache, writing only
    /// the shard's KV-head rows of each block.
    pub fn scatter_prefill_shard(
        &mut self,
        cache: &Tensor,
        slot: usize,
        shard: usize,
        n_shards: usize,
    ) -> crate::Result<()> {
        let (h0, h1) = self.shard_heads(shard, n_shards)?;
        let Some(seq) = self.seq(slot) else { return Ok(()) };
        let tok_len = seq.tok_len;
        self.scatter_range_heads(cache, slot, self.m_max,
                                 self.m_max + tok_len, h0, h1);
        Ok(())
    }

    /// `scatter_decode_row` for a shard-local cache.
    pub fn scatter_decode_row_shard(
        &mut self,
        cache: &Tensor,
        slot: usize,
        shard: usize,
        n_shards: usize,
    ) -> crate::Result<()> {
        let (h0, h1) = self.shard_heads(shard, n_shards)?;
        let Some(seq) = self.seq(slot) else { return Ok(()) };
        let p = self.m_max + seq.tok_len;
        if p < self.cap {
            self.scatter_range_heads(cache, slot, p, p + 1, h0, h1);
        }
        Ok(())
    }

    fn shard_heads(&self, shard: usize, n_shards: usize)
                   -> crate::Result<(usize, usize)> {
        let hkv = self.geometry().1;
        anyhow::ensure!(
            n_shards >= 1 && shard < n_shards && hkv % n_shards == 0,
            "kvpool: shard {shard}/{n_shards} invalid for {hkv} KV heads"
        );
        let loc = hkv / n_shards;
        Ok((shard * loc, (shard + 1) * loc))
    }

    /// One lane's `[L, 2, Hkv, CAP, dh]` view (tests).
    pub fn lane_view(&self, slot: usize) -> Tensor {
        let full = self.gather_view();
        let (nl, hkv, dh, _) = self.geometry();
        let mut lane = Tensor::zeros(&[nl, 2, hkv, self.cap, dh]);
        let row = self.cap * dh;
        for l in 0..nl {
            for w in 0..2 {
                for h in 0..hkv {
                    let src = (((l * 2 + w) * self.n_slots + slot) * hkv + h) * row;
                    let dst = ((l * 2 + w) * hkv + h) * row;
                    lane.data[dst..dst + row]
                        .copy_from_slice(&full.data[src..src + row]);
                }
            }
        }
        lane
    }

    /// Copy a lane's mapped blocks into the contiguous buffer (helper of
    /// `gather_view`). Unmapped positions stay zero.
    fn gather_into(&self, data: &mut [f32], slot: usize) {
        let (nl, hkv, dh, bs) = self.geometry();
        let blocks: &[usize] = match self.seq(slot) {
            Some(s) => &s.blocks,
            None => self.cushion_run(),
        };
        for (bi, &id) in blocks.iter().enumerate() {
            let p0 = bi * bs;
            let p1 = ((bi + 1) * bs).min(self.cap);
            if p0 >= p1 {
                break;
            }
            let block = self.pool_ref().block(id);
            for l in 0..nl {
                for w in 0..2 {
                    for h in 0..hkv {
                        let src = self.pool_ref().dims().row(l, w, h, 0);
                        let dst = ((((l * 2 + w) * self.n_slots + slot) * hkv
                            + h)
                            * self.cap
                            + p0)
                            * dh;
                        let n = (p1 - p0) * dh;
                        data[dst..dst + n].copy_from_slice(&block[src..src + n]);
                    }
                }
            }
        }
    }

    /// Mirror a prefill's written token positions `[m_max, m_max +
    /// tok_len)` from the contiguous cache back into this sequence's
    /// owned (non-shared) blocks.
    pub fn scatter_prefill(&mut self, cache: &Tensor, slot: usize) {
        let Some(seq) = self.seq(slot) else { return };
        let tok_len = seq.tok_len;
        self.scatter_range(cache, slot, self.m_max, self.m_max + tok_len);
    }

    /// Mirror the single KV row a decode step just wrote for this slot
    /// (position `m_max + tok_len` — call *before* `push_token`).
    pub fn scatter_decode_row(&mut self, cache: &Tensor, slot: usize) {
        let Some(seq) = self.seq(slot) else { return };
        let p = self.m_max + seq.tok_len;
        if p >= self.cap {
            return;
        }
        self.scatter_range(cache, slot, p, p + 1);
    }

    fn scatter_range(&mut self, cache: &Tensor, slot: usize, lo: usize, hi: usize) {
        let hkv = self.geometry().1;
        self.scatter_range_heads(cache, slot, lo, hi, 0, hkv);
    }

    /// Scatter substrate shared by the full and sharded mirrors: `cache`
    /// holds heads `[h0, h1)` only; block rows outside that range are
    /// untouched (they belong to other shards).
    fn scatter_range_heads(&mut self, cache: &Tensor, slot: usize, lo: usize,
                           hi: usize, h0: usize, h1: usize) {
        let (nl, _hkv, dh, bs) = self.geometry();
        let loc = h1 - h0;
        assert_eq!(
            cache.shape,
            vec![nl, 2, self.n_slots, loc, self.cap, dh],
            "scatter: cache/view shape mismatch"
        );
        let Some(seq) = self.seq(slot) else { return };
        let plan: Vec<(usize, usize, usize)> = seq
            .blocks
            .iter()
            .enumerate()
            .filter(|&(bi, _)| !seq.shared[bi])
            .filter_map(|(bi, &id)| {
                let p0 = (bi * bs).max(lo);
                let p1 = ((bi + 1) * bs).min(hi).min(self.cap);
                (p0 < p1).then_some((id, p0, p1))
            })
            .collect();
        for (id, p0, p1) in plan {
            for l in 0..nl {
                for w in 0..2 {
                    for h in 0..loc {
                        let src = ((((l * 2 + w) * self.n_slots + slot) * loc
                            + h)
                            * self.cap
                            + p0)
                            * dh;
                        let dst =
                            self.pool_ref().dims().row(l, w, h0 + h, p0 % bs);
                        let n = (p1 - p0) * dh;
                        self.pool_mut().block_mut(id)[dst..dst + n]
                            .copy_from_slice(&cache.data[src..src + n]);
                    }
                }
            }
        }
    }

    // -- native-path operand plumbing -------------------------------------

    /// One lane's block table as i32 ids (native prefill operand).
    pub fn table_i32(&self, slot: usize) -> Option<IntTensor> {
        let seq = self.seq(slot)?;
        Some(IntTensor::vec(seq.blocks.iter().map(|&b| b as i32).collect()))
    }

    /// All lanes' tables as `[B, W]`, -1-padded (native decode operand).
    /// Lanes without a sequence are all -1.
    pub fn tables_tensor(&self) -> IntTensor {
        let w = (0..self.n_slots)
            .filter_map(|s| self.seq(s).map(|q| q.blocks.len()))
            .max()
            .unwrap_or(0)
            .max(1);
        let mut data = vec![-1i32; self.n_slots * w];
        for slot in 0..self.n_slots {
            if let Some(seq) = self.seq(slot) {
                for (i, &id) in seq.blocks.iter().enumerate() {
                    data[slot * w + i] = id as i32;
                }
            }
        }
        IntTensor::new(vec![self.n_slots, w], data)
    }

    /// The pool as a `[n_blocks, L, 2, Hkv, BS, dh]` graph operand.
    pub fn pool_tensor(&self) -> Tensor {
        self.pool_ref().as_tensor()
    }

    /// Install a paged graph's functional pool output.
    pub fn install_pool(&mut self, t: &Tensor) -> crate::Result<()> {
        let (nl, hkv, dh, bs) = self.geometry();
        let n = self.pool_ref().n_blocks();
        anyhow::ensure!(
            t.shape == vec![n, nl, 2, hkv, bs, dh],
            "install_pool: shape {:?} does not match the pool",
            t.shape
        );
        self.pool_mut().install_data(&t.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kvpool::block::BlockDims;

    fn cushion() -> Tensor {
        Tensor::new(vec![1, 2, 1, 4, 2], (0..16).map(|i| i as f32).collect())
    }

    fn paged(cushion_kv: Option<&Tensor>) -> PagedKv {
        PagedKv::new(
            2,
            4,
            12,
            4,
            4,
            9,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 4 },
            cushion_kv,
        )
    }

    #[test]
    fn gather_view_matches_broadcast_builder() {
        let c = cushion();
        let kv = paged(Some(&c));
        let view = kv.gather_view();
        let want = cache_with_cushion(1, 1, 2, 2, 12, 4, Some(&c));
        assert_eq!(view.shape, want.shape);
        assert_eq!(view.data, want.data, "fresh pool view == cushion broadcast");
    }

    #[test]
    fn scatter_then_gather_roundtrips() {
        let c = cushion();
        let mut kv = paged(Some(&c));
        let slot = kv.alloc(1, 3).unwrap();
        // pretend a prefill wrote rows at positions [4, 7) of this lane
        let mut cache = kv.gather_view();
        for p in 4..7 {
            for w in 0..2 {
                let idx = (((w * 2 + slot) * 1) * 12 + p) * 2;
                cache.data[idx] = 100.0 + (w * 10 + p) as f32;
            }
        }
        kv.scatter_prefill(&cache, slot);
        let view = kv.gather_view();
        for p in 4..7 {
            for w in 0..2 {
                let idx = (((w * 2 + slot) * 1) * 12 + p) * 2;
                assert_eq!(view.data[idx], 100.0 + (w * 10 + p) as f32);
            }
        }
        // the other lane still shows the pristine cushion
        let other = 1 - slot;
        let want = cache_with_cushion(1, 1, 2, 2, 12, 4, Some(&c));
        let lane = kv.lane_view(other);
        for w in 0..2 {
            for p in 0..4 {
                let vi = ((w * 1) * 12 + p) * 2;
                let wi = (((w * 2 + other) * 1) * 12 + p) * 2;
                assert_eq!(lane.data[vi], want.data[wi]);
            }
        }
    }

    #[test]
    fn sharded_view_slices_heads_and_scatters_back() {
        // 2 KV heads so a 2-way shard owns one head each
        let c = Tensor::new(vec![1, 2, 2, 4, 2],
                            (0..32).map(|i| i as f32).collect());
        let dims = BlockDims {
            n_layers: 1, n_kv_heads: 2, d_head: 2, block_size: 4,
        };
        let mut kv = PagedKv::new(2, 4, 12, 4, 4, 9, dims, Some(&c));
        let full = kv.gather_view();
        // per-shard views are exact head slices of the full view
        for shard in 0..2 {
            let sv = kv.gather_view_shard(shard, 2).unwrap();
            assert_eq!(sv.shape, vec![1, 2, 2, 1, 12, 2]);
            let row = 12 * 2;
            for lw in 0..2 {
                for b in 0..2 {
                    let src = ((lw * 2 + b) * 2 + shard) * row;
                    let dst = (lw * 2 + b) * row;
                    assert_eq!(&sv.data[dst..dst + row],
                               &full.data[src..src + row]);
                }
            }
        }
        assert!(kv.gather_view_shard(0, 3).is_err(),
                "2 heads do not split 3 ways");
        // per-shard scatters compose to the full scatter: each shard
        // mirrors only its own head rows
        let slot = kv.alloc(1, 3).unwrap();
        for shard in 0..2 {
            let mut cache = kv.gather_view_shard(shard, 2).unwrap();
            for p in 4..7 {
                let idx = (slot * 12 + p) * 2; // layer 0, K, local head 0
                cache.data[idx] = 200.0 + (shard * 10 + p) as f32;
            }
            kv.scatter_prefill_shard(&cache, slot, shard, 2).unwrap();
        }
        let view = kv.gather_view();
        for shard in 0..2 {
            for p in 4..7 {
                let idx = ((slot * 2 + shard) * 12 + p) * 2;
                assert_eq!(view.data[idx], 200.0 + (shard * 10 + p) as f32);
            }
        }
    }

    #[test]
    fn tables_and_pool_tensor_shapes() {
        let mut kv = paged(None);
        let a = kv.alloc(1, 5).unwrap();
        let t = kv.tables_tensor();
        assert_eq!(t.shape, vec![2, 3]); // cushion + 2 token blocks
        let row = &t.data[a * 3..(a + 1) * 3];
        assert!(row.iter().all(|&v| v >= 0));
        let empty = 1 - a;
        assert!(t.data[empty * 3..(empty + 1) * 3].iter().all(|&v| v == -1));
        let pt = kv.pool_tensor();
        assert_eq!(pt.shape, vec![9, 1, 2, 1, 4, 2]);
        assert!(kv.install_pool(&pt).is_ok());
        assert!(kv
            .install_pool(&Tensor::zeros(&[1, 1, 2, 1, 4, 2]))
            .is_err());
    }
}
