//! Paged KV pool with shared cushion-prefix blocks, prefix caching, and
//! the admission/growth surface preemptive continuous batching runs on.
//!
//! The paper prepends one fixed CushionCache prefix to *every* sequence;
//! pre-paging, the serving cache physically copied that identical prefix
//! KV into all `B` slots and reserved a fixed capacity per slot, so
//! memory scaled as `B x (m_max + cap)` regardless of live demand, and a
//! full cache meant rejection. Here the physical cache is `n_blocks`
//! fixed-size blocks (`[L, 2, Hkv, BS, dh]` each):
//!
//! * `block`  — the refcounted block pool (alloc/release/pin/COW)
//! * `prefix` — content-keyed prefix cache over full prompt blocks
//!              (chained hashes, LRU eviction of idle entries)
//! * `paged`  — `PagedKv`: lanes + block tables + admission math +
//!              block-by-block decode growth; the cushion lives in one
//!              pinned shared block run every table points at
//! * `view`   — gather/scatter bridge to the contiguous per-batch cache
//!              the compiled graphs consume, plus operand plumbing for
//!              the native block-table graphs (`*_paged_*`)

pub mod block;
pub mod paged;
pub mod prefix;
pub mod view;

pub use block::{BlockDims, BlockId, BlockPool};
pub use paged::{PagedKv, PoolStats, SeqKv};
pub use prefix::{chain_hash, PrefixIndex};
pub use view::cache_with_cushion;
