//! The serving coordinator — the L3 runtime a deployment would run.
//!
//! Shaped like a miniature vLLM router/engine split:
//! * `request`   — request/response types and ids
//! * `kvpool`    — paged KV pool: refcounted fixed-size blocks, the
//!                 CushionCache prefix in one pinned shared block run,
//!                 content-keyed prefix caching, and the gather/scatter
//!                 views the execution graphs consume
//! * `engine`    — prefill/decode execution with the per-batch cache
//!                 view kept on device between steps (plus the native
//!                 block-table path on the reference backend)
//! * `batcher`   — admission queue with continuous-batching policy and
//!                 an age-based anti-starvation rule over preempted
//!                 (resumable) sequences
//! * `scheduler` — the step loop: admit by lane *and block*
//!                 availability, grow tables block-by-block, preempt the
//!                 youngest running sequence when the pool runs dry;
//!                 request-level faults become `FinishReason::Error`
//!                 responses, never engine errors
//! * `router`    — routes requests across engines (per quantization mode
//!                 or replicas); `ServeBackend` abstracts one-vs-many for
//!                 the server
//! * `server`    — TCP line-protocol front end: streaming per-token
//!                 lines, bounded admission, disconnect cancellation
//! * `metrics`   — TTFT / TPOT / throughput accounting (Table 8),
//!                 errored / rejected / cancelled fault-path counters,
//!                 and pool gauges (blocks in use / shared / preemptions)

pub mod batcher;
pub mod engine;
pub mod kvpool;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod telemetry;

pub use engine::Engine;
pub use kvpool::{PagedKv, PoolStats};
pub use request::{FinishReason, Request, RequestId, Response};
pub use router::{Health, ReplicaHealth, Router, ServeBackend};
pub use scheduler::Scheduler;
