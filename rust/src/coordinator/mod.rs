//! The serving coordinator — the L3 runtime a deployment would run.
//!
//! Shaped like a miniature vLLM router/engine split:
//! * `request`   — request/response types and ids
//! * `kvcache`   — slot manager over the device-resident paged KV cache,
//!                 with the CushionCache preloaded into every slot's
//!                 prefix region
//! * `engine`    — PJRT execution of prefill/decode with the cache kept
//!                 on device between steps
//! * `batcher`   — FIFO admission queue with continuous-batching policy
//! * `scheduler` — the step loop: admit-prefills-into-every-free-slot,
//!                 decode-all-running; request-level faults become
//!                 `FinishReason::Error` responses, never engine errors
//! * `router`    — routes requests across engines (per quantization mode
//!                 or replicas); `ServeBackend` abstracts one-vs-many for
//!                 the server
//! * `server`    — TCP line-protocol front end: streaming per-token
//!                 lines, bounded admission, disconnect cancellation
//! * `metrics`   — TTFT / TPOT / throughput accounting (Table 8) plus
//!                 errored / rejected / cancelled fault-path counters

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use engine::Engine;
pub use request::{FinishReason, Request, RequestId, Response};
pub use router::{Router, ServeBackend};
pub use scheduler::Scheduler;
