//! Serving metrics: TTFT, TPOT, throughput (the Table 8 quantities),
//! plus host<->device transfer accounting (bytes uploaded/fetched since
//! the metrics were created) so the residency of loop-invariant operands
//! is observable — see runtime::transfer and model::resident.

use std::time::Instant;

use crate::runtime::transfer::{self, TransferStats};
use crate::util::stats;

use super::request::Response;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// Process-wide transfer counters at creation time; `transfer()`
    /// reports movement since then.
    xfer_base: TransferStats,
    pub prefill_seconds: Vec<f64>,
    pub decode_seconds: Vec<f64>,
    pub decode_batch_sizes: Vec<usize>,
    pub ttft: Vec<f64>,
    pub tpot: Vec<f64>,
    pub completed: usize,
    pub tokens_out: usize,
    /// Requests that ended with `FinishReason::Error` (admission
    /// rejections and per-request execution failures).
    pub errored: usize,
    /// Requests refused before admission (bounded-queue overload).
    pub rejected: usize,
    /// Requests cancelled in flight (client disconnect / shutdown).
    pub cancelled: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            xfer_base: transfer::snapshot(),
            prefill_seconds: Vec::new(),
            decode_seconds: Vec::new(),
            decode_batch_sizes: Vec::new(),
            ttft: Vec::new(),
            tpot: Vec::new(),
            completed: 0,
            tokens_out: 0,
            errored: 0,
            rejected: 0,
            cancelled: 0,
        }
    }

    /// Host<->device transfers since these metrics were created. The
    /// counters are process-global, so co-resident engines share them.
    pub fn transfer(&self) -> TransferStats {
        transfer::snapshot().delta_since(&self.xfer_base)
    }

    pub fn record_prefill(&mut self, sec: f64) {
        self.prefill_seconds.push(sec);
    }

    pub fn record_decode(&mut self, sec: f64, batch: usize) {
        self.decode_seconds.push(sec);
        self.decode_batch_sizes.push(batch);
    }

    pub fn record_finished(&mut self, r: &Response) {
        if r.finished.is_error() {
            self.errored += 1;
            self.tokens_out += r.tokens.len();
            return;
        }
        self.completed += 1;
        self.tokens_out += r.tokens.len();
        self.ttft.push(r.ttft);
        self.tpot.extend_from_slice(&r.tpot);
    }

    /// A request refused before admission (queue overload).
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// A request cancelled in flight.
    pub fn record_cancelled(&mut self) {
        self.cancelled += 1;
    }

    pub fn summary(&self) -> MetricsSummary {
        let xfer = self.transfer();
        MetricsSummary {
            uploads: xfer.uploads,
            bytes_uploaded: xfer.bytes_uploaded,
            fetches: xfer.fetches,
            bytes_fetched: xfer.bytes_fetched,
            completed: self.completed,
            errored: self.errored,
            rejected: self.rejected,
            cancelled: self.cancelled,
            tokens_out: self.tokens_out,
            elapsed: self.started.elapsed().as_secs_f64(),
            ttft_mean: stats::mean(&self.ttft),
            ttft_p99: stats::percentile(&self.ttft, 99.0),
            tpot_mean: stats::mean(&self.tpot),
            tpot_std: stats::std(&self.tpot),
            tpot_p99: stats::percentile(&self.tpot, 99.0),
            decode_mean: stats::mean(&self.decode_seconds),
            prefill_mean: stats::mean(&self.prefill_seconds),
            mean_batch: stats::mean(
                &self.decode_batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
            ),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSummary {
    pub completed: usize,
    pub errored: usize,
    pub rejected: usize,
    pub cancelled: usize,
    pub uploads: u64,
    pub bytes_uploaded: u64,
    pub fetches: u64,
    pub bytes_fetched: u64,
    pub tokens_out: usize,
    pub elapsed: f64,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub tpot_mean: f64,
    pub tpot_std: f64,
    pub tpot_p99: f64,
    pub decode_mean: f64,
    pub prefill_mean: f64,
    pub mean_batch: f64,
}

impl MetricsSummary {
    pub fn tokens_per_second(&self) -> f64 {
        if self.elapsed == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.elapsed
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn summary_aggregates() {
        let mut m = Metrics::new();
        m.record_prefill(0.1);
        m.record_decode(0.05, 3);
        m.record_finished(&Response {
            id: 1,
            tokens: vec![1, 2, 3],
            ttft: 0.12,
            tpot: vec![0.05, 0.06],
            finished: FinishReason::MaxTokens,
            echo_text: false,
        });
        m.record_finished(&Response {
            id: 2,
            tokens: vec![],
            ttft: 0.0,
            tpot: vec![],
            finished: FinishReason::Error("prompt does not fit".into()),
            echo_text: false,
        });
        m.record_rejected();
        m.record_cancelled();
        let s = m.summary();
        assert_eq!(s.completed, 1);
        assert_eq!(s.errored, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.tokens_out, 3);
        assert!((s.tpot_mean - 0.055).abs() < 1e-9);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert!(s.tokens_per_second() > 0.0);
    }
}
