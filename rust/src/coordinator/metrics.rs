//! Serving metrics: TTFT, TPOT, throughput (the Table 8 quantities),
//! decode-step latency distribution (histogram + p50/p99) and
//! steady-state bytes-per-step gauges, plus host<->device transfer
//! accounting (bytes uploaded/fetched since the metrics were created) so
//! both the residency of loop-invariant operands *and* the per-step
//! transfer budget are observable in `serve` output — see
//! runtime::transfer, model::resident, and README "Serving hot path".

use std::time::Instant;

use crate::runtime::collective::CollectiveStats;
use crate::runtime::transfer::{self, TransferStats};
use crate::util::stats;

use super::request::Response;

/// Upper bucket bounds (ms) of the decode-step latency histogram; the
/// final implicit bucket is +inf. Log-spaced: a CPU decode step lands
/// mid-range, a PCIe-bound or recompiling step in the tail.
pub const DECODE_HIST_MS: [f64; 8] = [0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// Process-wide transfer counters at creation time; `transfer()`
    /// reports movement since then.
    xfer_base: TransferStats,
    pub prefill_seconds: Vec<f64>,
    pub decode_seconds: Vec<f64>,
    pub decode_batch_sizes: Vec<usize>,
    /// Per-decode-step transfer deltas (scheduler-metered): the
    /// steady-state bytes-per-step gauges derive from these.
    pub decode_bytes_up: Vec<u64>,
    pub decode_bytes_down: Vec<u64>,
    /// Per-decode-step collective deltas (sharded engines): bytes moved
    /// shard-to-shard per step, metered separately from host transfers
    /// (runtime::collective::measure). Empty on unsharded engines.
    pub decode_bytes_gathered: Vec<u64>,
    pub decode_bytes_reduced: Vec<u64>,
    /// Per-decode-step shard execute-time skew (max - min, seconds) of
    /// the group run. Empty on unsharded engines.
    pub decode_shard_skew: Vec<f64>,
    pub ttft: Vec<f64>,
    pub tpot: Vec<f64>,
    pub completed: usize,
    pub tokens_out: usize,
    /// Requests that ended with `FinishReason::Error` (admission
    /// rejections and per-request execution failures).
    pub errored: usize,
    /// Requests refused before admission (bounded-queue overload).
    pub rejected: usize,
    /// Requests cancelled in flight (client disconnect / shutdown).
    pub cancelled: usize,
    /// Sequences preempted back to the queue because the KV block pool
    /// ran dry (each resumes later via re-prefill).
    pub preempted: usize,
    /// KV pool gauges (coordinator::kvpool): sampled by the scheduler
    /// each step; `pool_blocks_total` is fixed at engine setup.
    pub pool_blocks_total: usize,
    pub pool_blocks_in_use: usize,
    pub pool_blocks_peak: usize,
    /// Blocks currently referenced by >= 2 live sequences.
    pub pool_blocks_shared: usize,
    /// Block allocations avoided by sharing (cushion run + prefix-cache
    /// hits), current and peak.
    pub pool_blocks_saved: usize,
    pub pool_blocks_saved_peak: usize,
    /// Engine-call retries under the bounded-backoff recovery policy,
    /// by injected-fault cause (runtime::faults).
    pub retries_execute: usize,
    pub retries_upload: usize,
    pub retries_fetch: usize,
    /// Degradation-ladder downgrades survived (persistent faults that
    /// moved the engine down a rung instead of killing it).
    pub downgrades: usize,
    /// Current ladder rung: 0 device-split, 1 host-roundtrip,
    /// 2 interpreter.
    pub backend_rung: u32,
    /// Requests killed by their per-request deadline
    /// (`FinishReason::Error("deadline")`).
    pub deadline_expired: usize,
    /// Graceful-shutdown drain duration (seconds; 0 until a drain ran).
    pub drain_seconds: f64,
    /// Faults the armed plan actually injected (gauge mirror of
    /// `runtime::faults::stats().total()`, sampled per step) — chaos
    /// tests assert injection happened.
    pub faults_injected: u64,
    /// Replica fault-domain gauges (coordinator::router): health state
    /// changes of this replica (Healthy/Suspect/Broken/HalfOpen edges).
    pub health_transitions: usize,
    /// Circuit-breaker opens (this replica quarantined).
    pub breaker_opens: usize,
    /// Half-open probes started (quarantined replica offered traffic).
    pub breaker_probes: usize,
    /// Failover events: this replica's work migrated off it.
    pub failovers: usize,
    /// Sequences and queued requests migrated *off* this replica.
    pub migrated_sequences: usize,
    /// Tokens burned re-prefilling migrated sequences elsewhere
    /// (`prompt ++ generated` length summed over migrated runs).
    pub reprefill_tokens: usize,
    /// Requests load-shed with "overloaded" because every replica of
    /// this replica's mode was broken at failover time.
    pub shed_requests: usize,
    /// Batches failed honestly at the degradation-ladder floor — the
    /// router's strongest non-Err health signal.
    pub ladder_floor_errors: usize,
    /// Quantization-health gauges (runtime::trace act sampling): decode
    /// steps sampled, last/peak activation absmax across quant sites,
    /// and the cumulative clipped/total element counts against the
    /// static quantization ranges. A stale or missing cushion surfaces
    /// here as an absmax/clip-rate excursion (the paper's claim, live).
    pub act_samples: usize,
    pub act_absmax: f32,
    pub act_absmax_peak: f32,
    pub act_clipped: u64,
    pub act_elems: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            xfer_base: transfer::snapshot(),
            prefill_seconds: Vec::new(),
            decode_seconds: Vec::new(),
            decode_batch_sizes: Vec::new(),
            decode_bytes_up: Vec::new(),
            decode_bytes_down: Vec::new(),
            decode_bytes_gathered: Vec::new(),
            decode_bytes_reduced: Vec::new(),
            decode_shard_skew: Vec::new(),
            ttft: Vec::new(),
            tpot: Vec::new(),
            completed: 0,
            tokens_out: 0,
            errored: 0,
            rejected: 0,
            cancelled: 0,
            preempted: 0,
            pool_blocks_total: 0,
            pool_blocks_in_use: 0,
            pool_blocks_peak: 0,
            pool_blocks_shared: 0,
            pool_blocks_saved: 0,
            pool_blocks_saved_peak: 0,
            retries_execute: 0,
            retries_upload: 0,
            retries_fetch: 0,
            downgrades: 0,
            backend_rung: 0,
            deadline_expired: 0,
            drain_seconds: 0.0,
            faults_injected: 0,
            health_transitions: 0,
            breaker_opens: 0,
            breaker_probes: 0,
            failovers: 0,
            migrated_sequences: 0,
            reprefill_tokens: 0,
            shed_requests: 0,
            ladder_floor_errors: 0,
            act_samples: 0,
            act_absmax: 0.0,
            act_absmax_peak: 0.0,
            act_clipped: 0,
            act_elems: 0,
        }
    }

    /// Host<->device transfers since these metrics were created. The
    /// counters are process-global, so co-resident engines share them.
    pub fn transfer(&self) -> TransferStats {
        transfer::snapshot().delta_since(&self.xfer_base)
    }

    pub fn record_prefill(&mut self, sec: f64) {
        self.prefill_seconds.push(sec);
    }

    /// Record one batched decode step: wall-clock, running batch size,
    /// the transfer-counter delta over the step (what actually crossed
    /// the host boundary — runtime::transfer::measure), the collective
    /// delta (shard-to-shard bytes, zero when unsharded) and the shard
    /// execute-time skew of the step's group run.
    pub fn record_decode(
        &mut self,
        sec: f64,
        batch: usize,
        xfer: TransferStats,
        coll: CollectiveStats,
        shard_skew: f64,
    ) {
        self.decode_seconds.push(sec);
        self.decode_batch_sizes.push(batch);
        self.decode_bytes_up.push(xfer.bytes_uploaded);
        self.decode_bytes_down.push(xfer.bytes_fetched);
        if coll != CollectiveStats::default() || shard_skew > 0.0 {
            self.decode_bytes_gathered.push(coll.bytes_gathered);
            self.decode_bytes_reduced.push(coll.bytes_reduced);
            self.decode_shard_skew.push(shard_skew);
        }
    }

    pub fn record_finished(&mut self, r: &Response) {
        if r.finished.is_error() {
            self.errored += 1;
            self.tokens_out += r.tokens.len();
            return;
        }
        self.completed += 1;
        self.tokens_out += r.tokens.len();
        // `ttft` is None for requests that never produced a token
        // (e.g. cancelled after admission but before their first
        // decode); folding those in as 0.0 would fake instant TTFTs.
        if let Some(t) = r.ttft {
            self.ttft.push(t);
        }
        self.tpot.extend_from_slice(&r.tpot);
    }

    /// A request refused before admission (queue overload).
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// A request cancelled in flight.
    pub fn record_cancelled(&mut self) {
        self.cancelled += 1;
    }

    /// A running sequence preempted back to the queue (pool pressure).
    pub fn record_preempted(&mut self) {
        self.preempted += 1;
    }

    /// One engine-call retry under the bounded-backoff policy, by the
    /// injected fault's cause ("execute" | "upload" | "fetch").
    pub fn record_retry(&mut self, cause: &str) {
        match cause {
            "execute" => self.retries_execute += 1,
            "upload" => self.retries_upload += 1,
            _ => self.retries_fetch += 1,
        }
    }

    pub fn retries_total(&self) -> usize {
        self.retries_execute + self.retries_upload + self.retries_fetch
    }

    /// A degradation-ladder downgrade to `rung`.
    pub fn record_downgrade(&mut self, rung: u32) {
        self.downgrades += 1;
        self.backend_rung = rung;
    }

    /// A request killed by its deadline.
    pub fn record_deadline_expired(&mut self) {
        self.deadline_expired += 1;
    }

    /// Graceful-shutdown drain completed in `seconds`.
    pub fn record_drain(&mut self, seconds: f64) {
        self.drain_seconds = seconds;
    }

    /// Mirror the armed fault plan's injection total (gauge).
    pub fn record_faults_injected(&mut self, total: u64) {
        self.faults_injected = total;
    }

    /// One health-state edge of this replica (router fault domain).
    pub fn record_health_transition(&mut self) {
        self.health_transitions += 1;
    }

    /// This replica's circuit breaker opened (quarantine).
    pub fn record_breaker_open(&mut self) {
        self.breaker_opens += 1;
    }

    /// This replica's breaker half-opened (probe traffic admitted).
    pub fn record_breaker_probe(&mut self) {
        self.breaker_probes += 1;
    }

    /// This replica's in-flight work was migrated off it: one failover
    /// moving `migrated` work items, costing `reprefill_tokens` of
    /// re-prefill on the destinations.
    pub fn record_failover(&mut self, migrated: usize, reprefill_tokens: usize) {
        self.failovers += 1;
        self.migrated_sequences += migrated;
        self.reprefill_tokens += reprefill_tokens;
    }

    /// A request load-shed because no healthy replica could take it.
    pub fn record_shed(&mut self) {
        self.shed_requests += 1;
    }

    /// A batch failed honestly at the degradation-ladder floor.
    pub fn record_floor_error(&mut self) {
        self.ladder_floor_errors += 1;
    }

    /// Fold one sampled decode step's activation-health aggregate
    /// (runtime::trace::act_end) into the quantization-health gauges.
    pub fn record_act_sample(&mut self, s: crate::runtime::trace::ActSample) {
        self.act_samples += 1;
        self.act_absmax = s.absmax;
        self.act_absmax_peak = self.act_absmax_peak.max(s.absmax);
        self.act_clipped += s.clipped;
        self.act_elems += s.total;
    }

    /// Cumulative clip rate over all sampled steps (0 when nothing was
    /// sampled or no static-range site ran).
    pub fn act_clip_rate(&self) -> f64 {
        if self.act_elems == 0 {
            0.0
        } else {
            self.act_clipped as f64 / self.act_elems as f64
        }
    }

    /// Decode-step latency percentile by the nearest-rank rule: always
    /// an actual recorded step, so `decode_histogram` provably has a
    /// non-zero count in the bucket containing it. Both `summary()` and
    /// the histogram line derive from this one source
    /// (`decode_seconds`); the interpolated `stats::percentile` is NOT
    /// used for decode steps because it can land between two samples,
    /// inside an empty bucket — the consistency test below pins the
    /// agreement.
    pub fn decode_percentile(&self, p: f64) -> f64 {
        stats::percentile_nearest(&self.decode_seconds, p)
    }

    /// Sample the KV pool gauges (scheduler, once per step).
    pub fn record_pool(&mut self, stats: crate::coordinator::kvpool::PoolStats) {
        self.pool_blocks_total = stats.total;
        self.pool_blocks_in_use = stats.in_use;
        self.pool_blocks_peak = self.pool_blocks_peak.max(stats.in_use);
        self.pool_blocks_shared = stats.shared;
        self.pool_blocks_saved = stats.saved;
        self.pool_blocks_saved_peak = self.pool_blocks_saved_peak.max(stats.saved);
    }

    /// Decode-step latency histogram: counts per DECODE_HIST_MS bucket
    /// plus the trailing +inf bucket.
    pub fn decode_histogram(&self) -> [usize; DECODE_HIST_MS.len() + 1] {
        let mut h = [0usize; DECODE_HIST_MS.len() + 1];
        for &s in &self.decode_seconds {
            let ms = s * 1e3;
            let i = DECODE_HIST_MS
                .iter()
                .position(|&b| ms <= b)
                .unwrap_or(DECODE_HIST_MS.len());
            h[i] += 1;
        }
        h
    }

    /// One-line rendering of `decode_histogram` for serve output, e.g.
    /// `<=0.5ms:0 <=1ms:3 ... >64ms:0`.
    pub fn decode_histogram_line(&self) -> String {
        let h = self.decode_histogram();
        let mut parts: Vec<String> = DECODE_HIST_MS
            .iter()
            .zip(&h)
            .map(|(b, n)| format!("<={b}ms:{n}"))
            .collect();
        parts.push(format!(
            ">{}ms:{}",
            DECODE_HIST_MS[DECODE_HIST_MS.len() - 1],
            h[DECODE_HIST_MS.len()]
        ));
        parts.join(" ")
    }

    pub fn summary(&self) -> MetricsSummary {
        let xfer = self.transfer();
        let mean_u64 = |xs: &[u64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<u64>() as f64 / xs.len() as f64
            }
        };
        MetricsSummary {
            uploads: xfer.uploads,
            bytes_uploaded: xfer.bytes_uploaded,
            fetches: xfer.fetches,
            bytes_fetched: xfer.bytes_fetched,
            completed: self.completed,
            errored: self.errored,
            rejected: self.rejected,
            cancelled: self.cancelled,
            preempted: self.preempted,
            pool_blocks_total: self.pool_blocks_total,
            pool_blocks_in_use: self.pool_blocks_in_use,
            pool_blocks_peak: self.pool_blocks_peak,
            pool_blocks_shared: self.pool_blocks_shared,
            pool_blocks_saved: self.pool_blocks_saved,
            pool_blocks_saved_peak: self.pool_blocks_saved_peak,
            retries_execute: self.retries_execute,
            retries_upload: self.retries_upload,
            retries_fetch: self.retries_fetch,
            downgrades: self.downgrades,
            backend_rung: self.backend_rung,
            deadline_expired: self.deadline_expired,
            drain_seconds: self.drain_seconds,
            faults_injected: self.faults_injected,
            health_transitions: self.health_transitions,
            breaker_opens: self.breaker_opens,
            breaker_probes: self.breaker_probes,
            failovers: self.failovers,
            migrated_sequences: self.migrated_sequences,
            reprefill_tokens: self.reprefill_tokens,
            shed_requests: self.shed_requests,
            ladder_floor_errors: self.ladder_floor_errors,
            act_samples: self.act_samples,
            act_absmax: self.act_absmax,
            act_absmax_peak: self.act_absmax_peak,
            act_clip_rate: self.act_clip_rate(),
            tokens_out: self.tokens_out,
            elapsed: self.started.elapsed().as_secs_f64(),
            ttft_mean: stats::mean(&self.ttft),
            ttft_p99: stats::percentile(&self.ttft, 99.0),
            tpot_mean: stats::mean(&self.tpot),
            tpot_std: stats::std(&self.tpot),
            tpot_p99: stats::percentile(&self.tpot, 99.0),
            decode_mean: stats::mean(&self.decode_seconds),
            decode_p50: self.decode_percentile(50.0),
            decode_p99: self.decode_percentile(99.0),
            decode_bytes_up_per_step: mean_u64(&self.decode_bytes_up),
            decode_bytes_down_per_step: mean_u64(&self.decode_bytes_down),
            decode_bytes_gathered_per_step: mean_u64(&self.decode_bytes_gathered),
            decode_bytes_reduced_per_step: mean_u64(&self.decode_bytes_reduced),
            shard_skew_max: self
                .decode_shard_skew
                .iter()
                .copied()
                .fold(0.0, f64::max),
            prefill_mean: stats::mean(&self.prefill_seconds),
            mean_batch: stats::mean(
                &self.decode_batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
            ),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSummary {
    pub completed: usize,
    pub errored: usize,
    pub rejected: usize,
    pub cancelled: usize,
    /// Pool-pressure preemptions (sequences later resumed by re-prefill).
    pub preempted: usize,
    /// Paged KV pool gauges: size, occupancy (last + peak), sharing
    /// (blocks multi-referenced now), and the allocations sharing saved
    /// (last + peak).
    pub pool_blocks_total: usize,
    pub pool_blocks_in_use: usize,
    pub pool_blocks_peak: usize,
    pub pool_blocks_shared: usize,
    pub pool_blocks_saved: usize,
    pub pool_blocks_saved_peak: usize,
    /// Fault-recovery counters: retries by injected cause, ladder
    /// downgrades (+ current rung), deadline kills, drain duration, and
    /// the injection total the armed plan reported.
    pub retries_execute: usize,
    pub retries_upload: usize,
    pub retries_fetch: usize,
    pub downgrades: usize,
    pub backend_rung: u32,
    pub deadline_expired: usize,
    pub drain_seconds: f64,
    pub faults_injected: u64,
    /// Replica fault-domain counters (router health machine + breaker +
    /// failover migration): see the matching `Metrics` fields.
    pub health_transitions: usize,
    pub breaker_opens: usize,
    pub breaker_probes: usize,
    pub failovers: usize,
    pub migrated_sequences: usize,
    pub reprefill_tokens: usize,
    pub shed_requests: usize,
    pub ladder_floor_errors: usize,
    /// Quantization-health gauges (see `Metrics::record_act_sample`).
    pub act_samples: usize,
    pub act_absmax: f32,
    pub act_absmax_peak: f32,
    pub act_clip_rate: f64,
    pub uploads: u64,
    pub bytes_uploaded: u64,
    pub fetches: u64,
    pub bytes_fetched: u64,
    pub tokens_out: usize,
    pub elapsed: f64,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub tpot_mean: f64,
    pub tpot_std: f64,
    pub tpot_p99: f64,
    pub decode_mean: f64,
    pub decode_p50: f64,
    pub decode_p99: f64,
    /// Steady-state transfer budget gauges: mean bytes crossing the host
    /// boundary per decode step (up = uploads, down = fetches). In the
    /// default device-resident + device-sampled mode these sit in the
    /// low hundreds of bytes; the seed round-tripped ~9 MB.
    pub decode_bytes_up_per_step: f64,
    pub decode_bytes_down_per_step: f64,
    /// Collective-traffic gauges (sharded engines, zero otherwise): mean
    /// shard-to-shard bytes per decode step, by collective kind, and the
    /// worst per-step shard execute-time skew (max - min, seconds).
    pub decode_bytes_gathered_per_step: f64,
    pub decode_bytes_reduced_per_step: f64,
    pub shard_skew_max: f64,
    pub prefill_mean: f64,
    pub mean_batch: f64,
}

impl MetricsSummary {
    pub fn tokens_per_second(&self) -> f64 {
        if self.elapsed == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.elapsed
        }
    }

    /// Combined (up + down) steady-state bytes per decode step.
    pub fn decode_bytes_per_step(&self) -> f64 {
        self.decode_bytes_up_per_step + self.decode_bytes_down_per_step
    }

    /// Peak pool utilization (fraction of blocks in use).
    pub fn pool_peak_utilization(&self) -> f64 {
        if self.pool_blocks_total == 0 {
            0.0
        } else {
            self.pool_blocks_peak as f64 / self.pool_blocks_total as f64
        }
    }
}

/// Per-request-class SLO accounting: TTFT/TPOT latency distributions
/// keyed by a caller-assigned class label ("short" / "long" in the
/// trace-replay workload), plus goodput-under-deadline. A request is
/// *good* when it finished without error — the scheduler converts a
/// lapsed deadline into `FinishReason::Error("deadline")`, so "finished
/// clean" and "met its deadline" coincide. Responses whose `ttft` is
/// `None` (never produced a token) count toward totals and badput but
/// contribute no latency samples.
///
/// This is a side-car to [`Metrics`], not part of it: classes exist
/// only where a workload generator assigns them (bench::scenario), and
/// the serving path proper stays class-blind.
#[derive(Debug, Default)]
pub struct SloMetrics {
    classes: std::collections::BTreeMap<String, SloClass>,
}

#[derive(Debug, Default)]
struct SloClass {
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    total: usize,
    good: usize,
    good_tokens: usize,
}

/// Percentile summary for one request class.
#[derive(Clone, Debug)]
pub struct SloClassSummary {
    pub class: String,
    pub total: usize,
    /// Requests that finished clean (within deadline, no error).
    pub good: usize,
    /// Tokens produced by good requests.
    pub good_tokens: usize,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p99: f64,
}

impl SloClassSummary {
    /// Goodput under deadline: the fraction of this class's requests
    /// that completed cleanly. 0.0 when no requests were recorded.
    pub fn goodput(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.good as f64 / self.total as f64
        }
    }
}

impl SloMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a finished response under `class`.
    pub fn record(&mut self, class: &str, r: &Response) {
        let c = self.classes.entry(class.to_string()).or_default();
        c.total += 1;
        if !r.finished.is_error() {
            c.good += 1;
            c.good_tokens += r.tokens.len();
        }
        if let Some(t) = r.ttft {
            c.ttft.push(t);
        }
        c.tpot.extend_from_slice(&r.tpot);
    }

    /// Summaries in class-name order (BTreeMap keeps this deterministic
    /// for trace-replay determinism tests and bench JSON output).
    pub fn summary(&self) -> Vec<SloClassSummary> {
        self.classes
            .iter()
            .map(|(class, c)| SloClassSummary {
                class: class.clone(),
                total: c.total,
                good: c.good,
                good_tokens: c.good_tokens,
                ttft_p50: stats::percentile(&c.ttft, 50.0),
                ttft_p99: stats::percentile(&c.ttft, 99.0),
                tpot_p50: stats::percentile(&c.tpot, 50.0),
                tpot_p99: stats::percentile(&c.tpot, 99.0),
            })
            .collect()
    }

    /// Worst (largest) TTFT p99 across all classes — the single number
    /// the bench gate watches.
    pub fn ttft_p99(&self) -> f64 {
        self.summary().iter().map(|s| s.ttft_p99).fold(0.0, f64::max)
    }

    /// Worst (largest) TPOT p99 across all classes.
    pub fn tpot_p99(&self) -> f64 {
        self.summary().iter().map(|s| s.tpot_p99).fold(0.0, f64::max)
    }

    /// Overall goodput across every class.
    pub fn goodput(&self) -> f64 {
        let (good, total) = self
            .classes
            .values()
            .fold((0usize, 0usize), |(g, t), c| (g + c.good, t + c.total));
        if total == 0 {
            0.0
        } else {
            good as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn summary_aggregates() {
        let mut m = Metrics::new();
        m.record_prefill(0.1);
        m.record_decode(
            0.05,
            3,
            TransferStats {
                uploads: 2,
                bytes_uploaded: 64,
                fetches: 1,
                bytes_fetched: 32,
            },
            CollectiveStats {
                all_gathers: 4,
                bytes_gathered: 512,
                all_reduces: 1,
                bytes_reduced: 128,
                ..Default::default()
            },
            0.002,
        );
        m.record_finished(&Response {
            id: 1,
            tokens: vec![1, 2, 3],
            ttft: Some(0.12),
            tpot: vec![0.05, 0.06],
            finished: FinishReason::MaxTokens,
            echo_text: false,
        });
        m.record_finished(&Response {
            id: 2,
            tokens: vec![],
            ttft: None,
            tpot: vec![],
            finished: FinishReason::Error("prompt does not fit".into()),
            echo_text: false,
        });
        m.record_rejected();
        m.record_cancelled();
        m.record_preempted();
        m.record_retry("execute");
        m.record_retry("execute");
        m.record_retry("upload");
        m.record_retry("fetch");
        m.record_downgrade(1);
        m.record_downgrade(2);
        m.record_deadline_expired();
        m.record_drain(1.5);
        m.record_faults_injected(7);
        m.record_health_transition();
        m.record_health_transition();
        m.record_breaker_open();
        m.record_breaker_probe();
        m.record_failover(3, 42);
        m.record_shed();
        m.record_floor_error();
        m.record_pool(crate::coordinator::kvpool::PoolStats {
            total: 16,
            in_use: 9,
            shared: 2,
            saved: 3,
        });
        m.record_pool(crate::coordinator::kvpool::PoolStats {
            total: 16,
            in_use: 5,
            shared: 1,
            saved: 1,
        });
        let s = m.summary();
        assert_eq!(s.completed, 1);
        assert_eq!(s.errored, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.preempted, 1);
        assert_eq!(s.pool_blocks_in_use, 5, "gauges track the last sample");
        assert_eq!(s.pool_blocks_peak, 9, "peak survives the drop");
        assert_eq!(s.pool_blocks_shared, 1);
        assert_eq!(s.pool_blocks_saved, 1);
        assert_eq!(s.pool_blocks_saved_peak, 3);
        assert!((s.pool_peak_utilization() - 9.0 / 16.0).abs() < 1e-9);
        assert_eq!(s.retries_execute, 2);
        assert_eq!(s.retries_upload, 1);
        assert_eq!(s.retries_fetch, 1);
        assert_eq!(m.retries_total(), 4);
        assert_eq!(s.downgrades, 2);
        assert_eq!(s.backend_rung, 2, "rung tracks the last downgrade");
        assert_eq!(s.deadline_expired, 1);
        assert!((s.drain_seconds - 1.5).abs() < 1e-9);
        assert_eq!(s.faults_injected, 7);
        assert_eq!(s.health_transitions, 2);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.breaker_probes, 1);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.migrated_sequences, 3);
        assert_eq!(s.reprefill_tokens, 42);
        assert_eq!(s.shed_requests, 1);
        assert_eq!(s.ladder_floor_errors, 1);
        assert_eq!(s.tokens_out, 3);
        assert!((s.tpot_mean - 0.055).abs() < 1e-9);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert!(s.tokens_per_second() > 0.0);
        assert!((s.decode_bytes_up_per_step - 64.0).abs() < 1e-9);
        assert!((s.decode_bytes_down_per_step - 32.0).abs() < 1e-9);
        assert!((s.decode_bytes_per_step() - 96.0).abs() < 1e-9);
        assert!((s.decode_bytes_gathered_per_step - 512.0).abs() < 1e-9);
        assert!((s.decode_bytes_reduced_per_step - 128.0).abs() < 1e-9);
        assert!((s.shard_skew_max - 0.002).abs() < 1e-9);
    }

    #[test]
    fn unsharded_steps_leave_collective_gauges_zero() {
        let mut m = Metrics::new();
        m.record_decode(
            0.01,
            2,
            TransferStats::default(),
            CollectiveStats::default(),
            0.0,
        );
        let s = m.summary();
        assert_eq!(s.decode_bytes_gathered_per_step, 0.0);
        assert_eq!(s.decode_bytes_reduced_per_step, 0.0);
        assert_eq!(s.shard_skew_max, 0.0);
    }

    #[test]
    fn decode_histogram_buckets() {
        let mut m = Metrics::new();
        for &s in &[0.0003, 0.0018, 0.0018, 0.030, 9.0] {
            m.record_decode(
                s,
                1,
                TransferStats::default(),
                CollectiveStats::default(),
                0.0,
            );
        }
        let h = m.decode_histogram();
        assert_eq!(h[0], 1, "0.3ms -> <=0.5ms");
        assert_eq!(h[2], 2, "1.8ms -> <=2ms");
        assert_eq!(h[6], 1, "30ms -> <=32ms");
        assert_eq!(h[DECODE_HIST_MS.len()], 1, "9s -> +inf");
        assert_eq!(h.iter().sum::<usize>(), 5);
        let line = m.decode_histogram_line();
        assert!(line.starts_with("<=0.5ms:1"));
        assert!(line.ends_with(">64ms:1"));
    }

    #[test]
    fn slo_metrics_track_classes_and_goodput() {
        let mut slo = SloMetrics::new();
        let ok = |id, ttft: f64, tpot: Vec<f64>| Response {
            id,
            tokens: vec![1, 2],
            ttft: Some(ttft),
            tpot,
            finished: FinishReason::MaxTokens,
            echo_text: false,
        };
        slo.record("short", &ok(1, 0.010, vec![0.002, 0.003]));
        slo.record("short", &ok(2, 0.030, vec![0.004]));
        slo.record("long", &ok(3, 0.200, vec![0.005]));
        // a deadline kill: errored, never produced a token
        slo.record(
            "long",
            &Response {
                id: 4,
                tokens: vec![],
                ttft: None,
                tpot: vec![],
                finished: FinishReason::Error("deadline".into()),
                echo_text: false,
            },
        );
        let s = slo.summary();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].class, "long", "BTreeMap order is deterministic");
        assert_eq!(s[1].class, "short");
        assert_eq!(s[0].total, 2);
        assert_eq!(s[0].good, 1);
        assert!((s[0].goodput() - 0.5).abs() < 1e-9);
        assert_eq!(s[1].good, 2);
        assert_eq!(s[1].good_tokens, 4);
        assert!(s[1].ttft_p50 <= s[1].ttft_p99, "percentiles ordered");
        assert!(s[1].tpot_p50 <= s[1].tpot_p99);
        // the None-ttft response contributed no latency sample
        assert!((s[0].ttft_p99 - 0.200).abs() < 1e-9);
        assert!((slo.ttft_p99() - 0.200).abs() < 1e-9);
        assert!(slo.tpot_p99() > 0.0);
        assert!((slo.goodput() - 0.75).abs() < 1e-9);
        assert_eq!(SloMetrics::new().goodput(), 0.0);
    }

    #[test]
    fn decode_percentiles_in_summary() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_decode(
                i as f64 / 1000.0,
                4,
                TransferStats::default(),
                CollectiveStats::default(),
                0.0,
            );
        }
        let s = m.summary();
        // nearest-rank: p50 of 1..=100 ms is the 50th sample exactly
        assert!((s.decode_p50 - 0.050).abs() < 1e-12);
        assert!((s.decode_p99 - 0.099).abs() < 1e-12);
    }

    /// The satellite fix: summary percentiles and the histogram must
    /// derive from one source. Nearest-rank percentiles are actual
    /// samples, so the histogram bucket containing p50/p99 always has a
    /// non-zero count — interpolated percentiles could land in an empty
    /// bucket (e.g. samples {0.4ms, 64.5ms}: interpolated p50 =
    /// 32.45ms falls in the empty `<=64ms` bucket).
    #[test]
    fn decode_percentiles_agree_with_histogram() {
        let cases: Vec<Vec<f64>> = vec![
            vec![0.0004, 0.0645],
            vec![0.0003, 0.0018, 0.0018, 0.030, 9.0],
            (1..=37).map(|i| i as f64 * 7e-4).collect(),
        ];
        for samples in cases {
            let mut m = Metrics::new();
            for &s in &samples {
                m.record_decode(
                    s,
                    1,
                    TransferStats::default(),
                    CollectiveStats::default(),
                    0.0,
                );
            }
            let h = m.decode_histogram();
            let s = m.summary();
            for (p, v) in [(50.0, s.decode_p50), (99.0, s.decode_p99)] {
                assert_eq!(v, m.decode_percentile(p), "one source for p{p}");
                assert!(
                    samples.contains(&v),
                    "p{p}={v} must be an actual sample"
                );
                let ms = v * 1e3;
                let bucket = DECODE_HIST_MS
                    .iter()
                    .position(|&b| ms <= b)
                    .unwrap_or(DECODE_HIST_MS.len());
                assert!(
                    h[bucket] > 0,
                    "p{p}={ms}ms lands in histogram bucket {bucket} with count 0"
                );
            }
        }
    }

    #[test]
    fn act_samples_track_last_peak_and_clip_rate() {
        use crate::runtime::trace::ActSample;
        let mut m = Metrics::new();
        assert_eq!(m.act_clip_rate(), 0.0);
        m.record_act_sample(ActSample { absmax: 4.0, clipped: 10, total: 100 });
        m.record_act_sample(ActSample { absmax: 2.0, clipped: 0, total: 100 });
        let s = m.summary();
        assert_eq!(s.act_samples, 2);
        assert_eq!(s.act_absmax, 2.0, "last sample");
        assert_eq!(s.act_absmax_peak, 4.0, "peak survives the drop");
        assert!((s.act_clip_rate - 0.05).abs() < 1e-12);
    }
}
