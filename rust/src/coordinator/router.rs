//! Multi-engine router: route requests to engines by quantization mode,
//! with least-loaded-*blocks* selection among replicas of the same mode
//! (free KV blocks first, queued+running load as the tie-break). A
//! tensor-parallel engine registers as a single replica — one scheduler
//! drives the whole shard group lock-step, so draining the router
//! drains each shard group to completion with the same semantics as an
//! unsharded engine.

use std::collections::HashMap;

use super::request::{Request, RequestId, Response};
use super::scheduler::Scheduler;

/// The front end serves either one scheduler or a mode router; this
/// trait is the surface the serving loop needs from both.
pub trait ServeBackend {
    /// Submit a request, optionally to a named quantization mode.
    /// `Err` carries a *routing* message (unknown mode) that the server
    /// turns into a per-request error line — never a loop failure.
    fn submit(&mut self, mode: Option<&str>, req: Request) -> Result<(), String>;
    fn has_work(&self) -> bool;
    fn step(&mut self) -> crate::Result<usize>;
    fn take_finished(&mut self) -> Vec<Response>;
    fn take_token_events(&mut self) -> Vec<(RequestId, i32)>;
    fn cancel(&mut self, id: RequestId) -> bool;
    fn cancel_all(&mut self);
    /// Enter drain mode (graceful shutdown): accepted work finishes,
    /// new submissions are rejected with "overloaded".
    fn drain(&mut self);
    /// Record how long the shutdown drain took.
    fn record_drain(&mut self, seconds: f64);
    /// Queued + running requests (the bounded-admission load measure).
    fn load(&self) -> usize;
    fn vocab(&self) -> usize;
    fn record_rejected(&mut self);
    /// Log the serving metrics (decode latency p50/p99 + histogram and
    /// the steady-state bytes-per-step transfer gauges) — called by the
    /// server at shutdown so the transfer budget is visible in `serve`
    /// output, not just the perf bench.
    fn log_metrics(&self);
}

/// One engine's metrics lines for serve output.
fn log_scheduler_metrics(tag: &str, sched: &Scheduler) {
    let s = sched.metrics.summary();
    log::info!(
        "{tag}: {} ok / {} err / {} rej / {} cancel; {:.1} tok/s; \
         decode mean {:.2} ms p50 {:.2} ms p99 {:.2} ms; \
         steady-state {:.0} B up + {:.0} B down per step",
        s.completed,
        s.errored,
        s.rejected,
        s.cancelled,
        s.tokens_per_second(),
        s.decode_mean * 1e3,
        s.decode_p50 * 1e3,
        s.decode_p99 * 1e3,
        s.decode_bytes_up_per_step,
        s.decode_bytes_down_per_step,
    );
    log::info!("{tag}: decode latency histogram {}", sched.metrics.decode_histogram_line());
    // live pool stats (not the step-sampled gauges): accurate even when
    // the server shuts down between steps
    let pool = sched.engine.kv.pool_stats();
    log::info!(
        "{tag}: kv pool {}/{} blocks in use (peak {}, {:.0}% peak util); \
         {} shared now, sharing saved {} allocations (peak {}); \
         {} preemptions, {} prefix-cache entries",
        pool.in_use,
        pool.total,
        s.pool_blocks_peak,
        s.pool_peak_utilization() * 100.0,
        pool.shared,
        pool.saved,
        s.pool_blocks_saved_peak,
        s.preempted,
        sched.engine.kv.prefix_cache_len(),
    );
    if sched.engine.n_shards() > 1 {
        log::info!(
            "{tag}: tensor-parallel {} shard(s): {:.0} B all-gathered + \
             {:.0} B all-reduced per decode step; worst shard step skew \
             {:.3} ms",
            sched.engine.n_shards(),
            s.decode_bytes_gathered_per_step,
            s.decode_bytes_reduced_per_step,
            s.shard_skew_max * 1e3,
        );
    }
    log::info!(
        "{tag}: fault recovery: {} fault(s) injected; retries {} execute \
         / {} upload / {} fetch; {} downgrade(s) (rung {}); {} deadline \
         kill(s); drain {:.2} s",
        s.faults_injected,
        s.retries_execute,
        s.retries_upload,
        s.retries_fetch,
        s.downgrades,
        s.backend_rung,
        s.deadline_expired,
        s.drain_seconds,
    );
}

impl ServeBackend for Scheduler {
    fn submit(&mut self, mode: Option<&str>, req: Request) -> Result<(), String> {
        match mode {
            None => {
                self.submit_request(req);
                Ok(())
            }
            Some(m) => Err(format!(
                "mode '{m}' unavailable: single-engine server (use the router)"
            )),
        }
    }

    fn has_work(&self) -> bool {
        Scheduler::has_work(self)
    }

    fn step(&mut self) -> crate::Result<usize> {
        Scheduler::step(self)
    }

    fn take_finished(&mut self) -> Vec<Response> {
        Scheduler::take_finished(self)
    }

    fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        Scheduler::take_token_events(self)
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        Scheduler::cancel(self, id)
    }

    fn cancel_all(&mut self) {
        Scheduler::cancel_all(self)
    }

    fn drain(&mut self) {
        Scheduler::drain(self)
    }

    fn record_drain(&mut self, seconds: f64) {
        self.metrics.record_drain(seconds);
    }

    fn load(&self) -> usize {
        self.batcher.waiting() + self.running_count()
    }

    fn vocab(&self) -> usize {
        self.engine.session.manifest.vocab
    }

    fn record_rejected(&mut self) {
        self.metrics.record_rejected();
    }

    fn log_metrics(&self) {
        log_scheduler_metrics("serve", self);
    }
}

pub struct Router {
    engines: Vec<(String, Scheduler)>,
    by_mode: HashMap<String, Vec<usize>>,
    assignments: HashMap<RequestId, usize>,
}

impl Router {
    pub fn new() -> Self {
        Self {
            engines: Vec::new(),
            by_mode: HashMap::new(),
            assignments: HashMap::new(),
        }
    }

    pub fn add_engine(&mut self, mode: &str, sched: Scheduler) {
        self.by_mode
            .entry(mode.to_string())
            .or_default()
            .push(self.engines.len());
        self.engines.push((mode.to_string(), sched));
    }

    pub fn modes(&self) -> Vec<String> {
        let mut m: Vec<String> = self.by_mode.keys().cloned().collect();
        m.sort();
        m
    }

    /// Route to the best replica serving `mode`: free KV blocks are the
    /// primary key (the real admission bottleneck — a replica with a
    /// deep queue but an empty pool is still the wrong place for a new
    /// prompt), queued+running load breaks ties. A tensor-parallel
    /// engine counts as *one* replica: its shards advance lock-step
    /// behind one scheduler, so its pool/load gauges already describe
    /// the whole group.
    pub fn route(&mut self, mode: &str, req: Request) -> crate::Result<()> {
        let idxs = self
            .by_mode
            .get(mode)
            .ok_or_else(|| anyhow::anyhow!("no engine for mode '{mode}'"))?;
        let &idx = idxs
            .iter()
            .min_by_key(|&&i| {
                let s = &self.engines[i].1;
                let pool = s.engine.kv.pool_stats();
                let free = pool.total.saturating_sub(pool.in_use);
                (
                    std::cmp::Reverse(free),
                    s.batcher.waiting() + s.running_count(),
                )
            })
            .unwrap();
        self.assignments.insert(req.id, idx);
        self.engines[idx].1.submit_request(req);
        Ok(())
    }

    /// Step every engine once; collects finished responses.
    pub fn step_all(&mut self) -> crate::Result<Vec<Response>> {
        let mut out = Vec::new();
        for (_, sched) in self.engines.iter_mut() {
            if sched.has_work() {
                sched.step()?;
            }
            for r in sched.take_finished() {
                self.assignments.remove(&r.id);
                out.push(r);
            }
        }
        Ok(out)
    }

    pub fn has_work(&self) -> bool {
        self.engines.iter().any(|(_, s)| s.has_work())
    }

    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        let mut all = Vec::new();
        while self.has_work() {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    pub fn scheduler_mut(&mut self, mode: &str) -> Option<&mut Scheduler> {
        let idx = *self.by_mode.get(mode)?.first()?;
        Some(&mut self.engines[idx].1)
    }

    pub fn pending_assignments(&self) -> usize {
        self.assignments.len()
    }

    /// Default mode when a request names none: the alphabetically first
    /// (stable, independent of registration order).
    pub fn default_mode(&self) -> Option<String> {
        self.modes().into_iter().next()
    }

    /// Cancel a routed request wherever it currently lives.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(idx) = self.assignments.remove(&id) {
            return self.engines[idx].1.cancel(id);
        }
        false
    }

    pub fn cancel_all(&mut self) {
        for (_, sched) in self.engines.iter_mut() {
            sched.cancel_all();
        }
        self.assignments.clear();
    }

    pub fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        let mut out = Vec::new();
        for (_, sched) in self.engines.iter_mut() {
            out.extend(sched.take_token_events());
        }
        out
    }

    /// Queued + running requests across every engine.
    pub fn load(&self) -> usize {
        self.engines
            .iter()
            .map(|(_, s)| s.batcher.waiting() + s.running_count())
            .sum()
    }
}

impl ServeBackend for Router {
    fn submit(&mut self, mode: Option<&str>, req: Request) -> Result<(), String> {
        let mode = match mode {
            Some(m) => m.to_string(),
            None => self
                .default_mode()
                .ok_or_else(|| "router has no engines".to_string())?,
        };
        self.route(&mode, req).map_err(|e| format!("{e:#}"))
    }

    fn has_work(&self) -> bool {
        Router::has_work(self)
    }

    fn step(&mut self) -> crate::Result<usize> {
        let mut produced = 0;
        for (_, sched) in self.engines.iter_mut() {
            if sched.has_work() {
                produced += sched.step()?;
            }
        }
        Ok(produced)
    }

    fn take_finished(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        for (_, sched) in self.engines.iter_mut() {
            for r in sched.take_finished() {
                self.assignments.remove(&r.id);
                out.push(r);
            }
        }
        out
    }

    fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        Router::take_token_events(self)
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        Router::cancel(self, id)
    }

    fn cancel_all(&mut self) {
        Router::cancel_all(self)
    }

    fn drain(&mut self) {
        for (_, sched) in self.engines.iter_mut() {
            sched.drain();
        }
    }

    fn record_drain(&mut self, seconds: f64) {
        if let Some((_, s)) = self.engines.first_mut() {
            // process-level gauge; by convention it lives on engine 0
            s.metrics.record_drain(seconds);
        }
    }

    fn load(&self) -> usize {
        Router::load(self)
    }

    fn vocab(&self) -> usize {
        self.engines
            .first()
            .map(|(_, s)| s.engine.session.manifest.vocab)
            .unwrap_or(0)
    }

    fn record_rejected(&mut self) {
        if let Some((_, s)) = self.engines.first_mut() {
            // process-level counter; by convention it lives on engine 0
            s.metrics.record_rejected();
        }
    }

    fn log_metrics(&self) {
        for (mode, sched) in &self.engines {
            log_scheduler_metrics(&format!("serve[{mode}]"), sched);
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Engine, Scheduler};
    use crate::quant::scheme::Scheme;
    use crate::testkit::tiny::TinyCfg;

    fn sched() -> Scheduler {
        Scheduler::new(
            Engine::new(TinyCfg::default().session().unwrap(), Scheme::fp())
                .unwrap(),
        )
    }

    fn prompt(s: &Scheduler) -> Vec<i32> {
        s.engine.session.corpus.split("heldout").unwrap().seq(0)[..4].to_vec()
    }

    #[test]
    fn block_starved_replica_stops_receiving_work() {
        let mut r = Router::new();
        r.add_engine("fp", sched());
        r.add_engine("fp", sched());
        // occupy replica 0's pool directly: fewer free blocks than the
        // idle replica 1, while both queues stay empty — under pure
        // load-based dispatch the replicas would look identical
        {
            let kv = &mut r.engines[0].1.engine.kv;
            let mut id = 100u64;
            while kv.alloc(id, 4).is_some() {
                id += 1;
            }
            assert!(
                kv.pool_stats().in_use > 0,
                "allocation must consume blocks"
            );
        }
        let free = |r: &Router, i: usize| {
            let p = r.engines[i].1.engine.kv.pool_stats();
            p.total - p.in_use
        };
        assert!(free(&r, 0) < free(&r, 1), "replica 0 must be starved");
        let p = prompt(&r.engines[1].1);
        for id in 0..3u64 {
            r.route("fp", Request::new(id, p.clone(), 2)).unwrap();
        }
        assert_eq!(
            r.engines[0].1.batcher.waiting(),
            0,
            "block-starved replica must stop receiving work"
        );
        assert_eq!(r.engines[1].1.batcher.waiting(), 3);
    }

    #[test]
    fn equal_pools_tie_break_on_load() {
        let mut r = Router::new();
        r.add_engine("fp", sched());
        r.add_engine("fp", sched());
        let p = prompt(&r.engines[0].1);
        r.route("fp", Request::new(1, p.clone(), 2)).unwrap();
        r.route("fp", Request::new(2, p, 2)).unwrap();
        assert_eq!(r.engines[0].1.batcher.waiting(), 1, "load breaks the tie");
        assert_eq!(r.engines[1].1.batcher.waiting(), 1);
    }
}
