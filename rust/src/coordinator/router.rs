//! Multi-engine router: route requests to engines by quantization mode,
//! with least-loaded selection among replicas of the same mode.

use std::collections::HashMap;

use super::request::{Request, RequestId, Response};
use super::scheduler::Scheduler;

pub struct Router {
    engines: Vec<(String, Scheduler)>,
    by_mode: HashMap<String, Vec<usize>>,
    assignments: HashMap<RequestId, usize>,
}

impl Router {
    pub fn new() -> Self {
        Self {
            engines: Vec::new(),
            by_mode: HashMap::new(),
            assignments: HashMap::new(),
        }
    }

    pub fn add_engine(&mut self, mode: &str, sched: Scheduler) {
        self.by_mode
            .entry(mode.to_string())
            .or_default()
            .push(self.engines.len());
        self.engines.push((mode.to_string(), sched));
    }

    pub fn modes(&self) -> Vec<String> {
        let mut m: Vec<String> = self.by_mode.keys().cloned().collect();
        m.sort();
        m
    }

    /// Route to the least-loaded replica serving `mode`.
    pub fn route(&mut self, mode: &str, req: Request) -> crate::Result<()> {
        let idxs = self
            .by_mode
            .get(mode)
            .ok_or_else(|| anyhow::anyhow!("no engine for mode '{mode}'"))?;
        let &idx = idxs
            .iter()
            .min_by_key(|&&i| {
                let s = &self.engines[i].1;
                s.batcher.waiting() + s.running_count()
            })
            .unwrap();
        self.assignments.insert(req.id, idx);
        self.engines[idx].1.submit_request(req);
        Ok(())
    }

    /// Step every engine once; collects finished responses.
    pub fn step_all(&mut self) -> crate::Result<Vec<Response>> {
        let mut out = Vec::new();
        for (_, sched) in self.engines.iter_mut() {
            if sched.has_work() {
                sched.step()?;
            }
            for r in sched.take_finished() {
                self.assignments.remove(&r.id);
                out.push(r);
            }
        }
        Ok(out)
    }

    pub fn has_work(&self) -> bool {
        self.engines.iter().any(|(_, s)| s.has_work())
    }

    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        let mut all = Vec::new();
        while self.has_work() {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    pub fn scheduler_mut(&mut self, mode: &str) -> Option<&mut Scheduler> {
        let idx = *self.by_mode.get(mode)?.first()?;
        Some(&mut self.engines[idx].1)
    }

    pub fn pending_assignments(&self) -> usize {
        self.assignments.len()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}
