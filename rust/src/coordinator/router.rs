//! Multi-engine router: route requests to engines by quantization mode,
//! with least-loaded-*blocks* selection among replicas of the same mode
//! (free KV blocks first, queued+running load as the tie-break). A
//! tensor-parallel engine registers as a single replica — one scheduler
//! drives the whole shard group lock-step, so draining the router
//! drains each shard group to completion with the same semantics as an
//! unsharded engine.
//!
//! Replica fault domains: each replica carries a health state machine
//! (`Healthy → Suspect → Broken`, with a `HalfOpen` probe state on the
//! way back) driven by three signals — engine-level step errors (a
//! whole-replica kill or a genuine engine bug), ladder-floor errors
//! (the replica is erroring batches at the degradation floor), and
//! step-latency outliers (a replica far over its siblings' median).
//! Breaking a replica opens its circuit breaker and triggers **failover
//! migration**: the scheduler is evacuated (`Scheduler::evacuate`) and
//! every queued request plus every running/preempted sequence is
//! reconstructed on a healthy sibling via the paged `prompt ++
//! generated` resume path — bit-identical in fp/static modes, no
//! request silently lost. Only when *every* replica of a mode is broken
//! is work load-shed with an honest "overloaded". The breaker counts
//! down over subsequent steps (seeded-deterministic jitter, doubling
//! backoff) to a half-open probe that re-admits traffic; enough clean
//! probe steps close the breaker, another failure reopens it wider.

use std::collections::HashMap;

use crate::runtime::trace;
use crate::util::prng::SplitMix64;

use super::request::{FinishReason, Request, RequestId, Response};
use super::scheduler::Scheduler;
use super::telemetry;

/// Suspicion strikes (floor errors / latency outliers) before a
/// Suspect replica is broken and failed over.
const SUSPECT_STRIKES: u32 = 3;
/// Clean steps that clear a Suspect replica back to Healthy.
const SUSPECT_CLEAR_OKS: u32 = 16;
/// Clean half-open probe steps that close the breaker.
const PROBE_OK_STEPS: u32 = 3;
/// First breaker-open interval, in router steps (doubles per reopen).
const BREAKER_BASE_STEPS: u64 = 8;
/// Backoff cap on the breaker-open interval.
const BREAKER_MAX_STEPS: u64 = 256;
/// A step is a latency outlier when it exceeds the sibling median by
/// this factor *and* the absolute floor (tiny test engines step in
/// microseconds — without the floor, scheduler noise would trip it).
const LATENCY_OUTLIER_FACTOR: f64 = 8.0;
const LATENCY_OUTLIER_FLOOR: f64 = 0.020;

/// One replica's health in the router's fault domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Serving normally.
    Healthy,
    /// Accumulating strikes (ladder-floor errors, latency outliers);
    /// still routable — enough strikes break it, enough clean steps
    /// clear it.
    Suspect,
    /// Quarantined: circuit open, no traffic, work migrated away. The
    /// breaker counts down to a half-open probe.
    Broken,
    /// Probing: routable again, but one more failure reopens the
    /// breaker with doubled backoff; enough clean steps close it.
    HalfOpen,
}

/// The per-replica health state machine + circuit breaker. Pure
/// bookkeeping (no engine references), so transitions are unit-testable
/// and the probe schedule is deterministic: jitter comes from a seeded
/// `SplitMix64`, never the wall clock.
#[derive(Debug)]
pub struct ReplicaHealth {
    state: Health,
    /// Suspicion strikes since the last clear.
    strikes: u32,
    /// Consecutive clean steps while Suspect.
    oks: u32,
    /// Router steps until the open breaker half-opens.
    probe_in: u64,
    /// Consecutive clean steps while HalfOpen.
    probe_ok: u32,
    /// Current open interval; doubles each reopen, capped.
    backoff: u64,
    rng: SplitMix64,
}

impl ReplicaHealth {
    pub fn new(seed: u64) -> Self {
        Self {
            state: Health::Healthy,
            strikes: 0,
            oks: 0,
            probe_in: 0,
            probe_ok: 0,
            backoff: BREAKER_BASE_STEPS,
            rng: SplitMix64::new(seed ^ 0xB12E_A4E2),
        }
    }

    pub fn state(&self) -> Health {
        self.state
    }

    /// Whether the router may send this replica traffic (everything but
    /// an open breaker).
    pub fn is_routable(&self) -> bool {
        !matches!(self.state, Health::Broken)
    }

    pub fn is_quarantined(&self) -> bool {
        matches!(self.state, Health::Broken)
    }

    /// Engine-level failure: open the breaker from any state. Returns
    /// the number of router steps until the half-open probe (seeded
    /// jitter over the current backoff, which then doubles).
    pub fn trip(&mut self) -> u64 {
        self.state = Health::Broken;
        self.strikes = 0;
        self.oks = 0;
        self.probe_ok = 0;
        self.probe_in = self.backoff + self.rng.next_u64() % (self.backoff / 2 + 1);
        self.backoff = (self.backoff * 2).min(BREAKER_MAX_STEPS);
        self.probe_in
    }

    /// A suspicion strike (ladder-floor error, latency outlier).
    /// Healthy becomes Suspect; returns true when the strikes have
    /// escalated past the threshold and the caller must break the
    /// replica (`trip` + failover).
    pub fn strike(&mut self) -> bool {
        match self.state {
            Health::Broken | Health::HalfOpen => false,
            _ => {
                self.oks = 0;
                self.strikes += 1;
                if self.state == Health::Healthy {
                    self.state = Health::Suspect;
                }
                self.strikes >= SUSPECT_STRIKES
            }
        }
    }

    /// A clean step completed on this replica. Suspect clears back to
    /// Healthy after enough of these; HalfOpen closes the breaker
    /// (returns true, backoff resets) after enough probe steps.
    pub fn note_ok(&mut self) -> bool {
        match self.state {
            Health::Suspect => {
                self.oks += 1;
                if self.oks >= SUSPECT_CLEAR_OKS {
                    self.state = Health::Healthy;
                    self.strikes = 0;
                    self.oks = 0;
                }
                false
            }
            Health::HalfOpen => {
                self.probe_ok += 1;
                if self.probe_ok >= PROBE_OK_STEPS {
                    self.state = Health::Healthy;
                    self.strikes = 0;
                    self.backoff = BREAKER_BASE_STEPS;
                    true
                } else {
                    false
                }
            }
            _ => false,
        }
    }

    /// One router step elapsed with the breaker open; returns true when
    /// the countdown reaches zero and the replica half-opens.
    pub fn tick(&mut self) -> bool {
        if self.state != Health::Broken {
            return false;
        }
        self.probe_in = self.probe_in.saturating_sub(1);
        if self.probe_in == 0 {
            self.state = Health::HalfOpen;
            self.probe_ok = 0;
            true
        } else {
            false
        }
    }
}

/// The front end serves either one scheduler or a mode router; this
/// trait is the surface the serving loop needs from both.
pub trait ServeBackend {
    /// Submit a request, optionally to a named quantization mode.
    /// `Err` carries a *routing* message (unknown mode, or every
    /// replica of the mode broken → "overloaded") that the server turns
    /// into a per-request error line — never a loop failure.
    fn submit(&mut self, mode: Option<&str>, req: Request) -> Result<(), String>;
    fn has_work(&self) -> bool;
    fn step(&mut self) -> crate::Result<usize>;
    fn take_finished(&mut self) -> Vec<Response>;
    fn take_token_events(&mut self) -> Vec<(RequestId, i32)>;
    fn cancel(&mut self, id: RequestId) -> bool;
    fn cancel_all(&mut self);
    /// Enter drain mode (graceful shutdown): accepted work finishes,
    /// new submissions are rejected with "overloaded".
    fn drain(&mut self);
    /// Record how long the shutdown drain took.
    fn record_drain(&mut self, seconds: f64);
    /// Queued + running requests (the bounded-admission load measure).
    fn load(&self) -> usize;
    fn vocab(&self) -> usize;
    fn record_rejected(&mut self);
    /// Log the serving metrics (decode latency p50/p99 + histogram and
    /// the steady-state bytes-per-step transfer gauges) — called by the
    /// server at shutdown so the transfer budget is visible in `serve`
    /// output, not just the perf bench.
    fn log_metrics(&self);
    /// Render the live serving metrics in Prometheus text format, one
    /// labeled sample set per replica (`coordinator::telemetry`) — the
    /// `{"cmd":"metrics"}` wire command and the `--metrics-interval`
    /// periodic snapshots read this mid-run.
    fn metrics_text(&self) -> String;
    /// Ladder-floor errors so far across the fleet. The server flushes
    /// a metrics snapshot whenever this advances, so a run that dies at
    /// the fault-ladder floor still leaves evidence behind.
    fn floor_errors(&self) -> usize;
}

/// One engine's metrics lines for serve output.
fn log_scheduler_metrics(tag: &str, sched: &Scheduler) {
    let s = sched.metrics.summary();
    log::info!(
        "{tag}: {} ok / {} err / {} rej / {} cancel; {:.1} tok/s; \
         decode mean {:.2} ms p50 {:.2} ms p99 {:.2} ms; \
         steady-state {:.0} B up + {:.0} B down per step",
        s.completed,
        s.errored,
        s.rejected,
        s.cancelled,
        s.tokens_per_second(),
        s.decode_mean * 1e3,
        s.decode_p50 * 1e3,
        s.decode_p99 * 1e3,
        s.decode_bytes_up_per_step,
        s.decode_bytes_down_per_step,
    );
    log::info!("{tag}: decode latency histogram {}", sched.metrics.decode_histogram_line());
    // live pool stats (not the step-sampled gauges): accurate even when
    // the server shuts down between steps
    let pool = sched.engine.kv.pool_stats();
    log::info!(
        "{tag}: kv pool {}/{} blocks in use (peak {}, {:.0}% peak util); \
         {} shared now, sharing saved {} allocations (peak {}); \
         {} preemptions, {} prefix-cache entries",
        pool.in_use,
        pool.total,
        s.pool_blocks_peak,
        s.pool_peak_utilization() * 100.0,
        pool.shared,
        pool.saved,
        s.pool_blocks_saved_peak,
        s.preempted,
        sched.engine.kv.prefix_cache_len(),
    );
    if sched.engine.n_shards() > 1 {
        log::info!(
            "{tag}: tensor-parallel {} shard(s): {:.0} B all-gathered + \
             {:.0} B all-reduced per decode step; worst shard step skew \
             {:.3} ms",
            sched.engine.n_shards(),
            s.decode_bytes_gathered_per_step,
            s.decode_bytes_reduced_per_step,
            s.shard_skew_max * 1e3,
        );
    }
    log::info!(
        "{tag}: fault recovery: {} fault(s) injected; retries {} execute \
         / {} upload / {} fetch; {} downgrade(s) (rung {}); {} deadline \
         kill(s); drain {:.2} s",
        s.faults_injected,
        s.retries_execute,
        s.retries_upload,
        s.retries_fetch,
        s.downgrades,
        s.backend_rung,
        s.deadline_expired,
        s.drain_seconds,
    );
    log::info!(
        "{tag}: fault domain: {} health transition(s); breaker {} open(s) \
         / {} probe(s); {} failover(s) migrating {} item(s) ({} re-prefill \
         tokens burned); {} shed; {} ladder-floor error(s)",
        s.health_transitions,
        s.breaker_opens,
        s.breaker_probes,
        s.failovers,
        s.migrated_sequences,
        s.reprefill_tokens,
        s.shed_requests,
        s.ladder_floor_errors,
    );
}

impl ServeBackend for Scheduler {
    fn submit(&mut self, mode: Option<&str>, req: Request) -> Result<(), String> {
        match mode {
            None => {
                self.submit_request(req);
                Ok(())
            }
            Some(m) => Err(format!(
                "mode '{m}' unavailable: single-engine server (use the router)"
            )),
        }
    }

    fn has_work(&self) -> bool {
        Scheduler::has_work(self)
    }

    fn step(&mut self) -> crate::Result<usize> {
        Scheduler::step(self)
    }

    fn take_finished(&mut self) -> Vec<Response> {
        Scheduler::take_finished(self)
    }

    fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        Scheduler::take_token_events(self)
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        Scheduler::cancel(self, id)
    }

    fn cancel_all(&mut self) {
        Scheduler::cancel_all(self)
    }

    fn drain(&mut self) {
        Scheduler::drain(self)
    }

    fn record_drain(&mut self, seconds: f64) {
        self.metrics.record_drain(seconds);
    }

    fn load(&self) -> usize {
        self.batcher.waiting() + self.running_count()
    }

    fn vocab(&self) -> usize {
        self.engine.session.manifest.vocab
    }

    fn record_rejected(&mut self) {
        self.metrics.record_rejected();
    }

    fn log_metrics(&self) {
        log_scheduler_metrics("serve", self);
    }

    fn metrics_text(&self) -> String {
        let labels = [
            ("mode", self.engine.scheme.label()),
            ("replica", "0".to_string()),
            ("shards", self.engine.n_shards().to_string()),
        ];
        telemetry::render_metrics(&self.metrics, &labels)
    }

    fn floor_errors(&self) -> usize {
        self.metrics.ladder_floor_errors
    }
}

pub struct Router {
    engines: Vec<(String, Scheduler)>,
    by_mode: HashMap<String, Vec<usize>>,
    assignments: HashMap<RequestId, usize>,
    /// Per-replica health + breaker, indexed like `engines`.
    health: Vec<ReplicaHealth>,
    /// Responses the router produced itself (load-shed at failover when
    /// no healthy sibling existed) — drained with the engines' finished.
    orphans: Vec<Response>,
    /// Seed for the breakers' deterministic probe jitter.
    seed: u64,
}

impl Router {
    pub fn new() -> Self {
        Self::with_seed(0xFA11_D0_33)
    }

    /// A router whose breaker probe schedules derive from `seed` —
    /// chaos tests pin the whole failover timeline with this.
    pub fn with_seed(seed: u64) -> Self {
        Self {
            engines: Vec::new(),
            by_mode: HashMap::new(),
            assignments: HashMap::new(),
            health: Vec::new(),
            orphans: Vec::new(),
            seed,
        }
    }

    pub fn add_engine(&mut self, mode: &str, sched: Scheduler) {
        let idx = self.engines.len();
        self.by_mode
            .entry(mode.to_string())
            .or_default()
            .push(idx);
        self.engines.push((mode.to_string(), sched));
        self.health.push(ReplicaHealth::new(
            self.seed ^ ((idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        ));
    }

    pub fn modes(&self) -> Vec<String> {
        let mut m: Vec<String> = self.by_mode.keys().cloned().collect();
        m.sort();
        m
    }

    pub fn replica_count(&self) -> usize {
        self.engines.len()
    }

    /// Replica `idx`'s current health (tests / diagnostics).
    pub fn replica_health(&self, idx: usize) -> Health {
        self.health[idx].state()
    }

    pub fn replica(&self, idx: usize) -> &Scheduler {
        &self.engines[idx].1
    }

    pub fn replica_mut(&mut self, idx: usize) -> &mut Scheduler {
        &mut self.engines[idx].1
    }

    /// Least-loaded-blocks pick among `idxs` (free KV blocks first,
    /// queued+running load breaks ties).
    fn pick_among(&self, idxs: &[usize]) -> Option<usize> {
        idxs.iter()
            .copied()
            .min_by_key(|&i| {
                let s = &self.engines[i].1;
                let pool = s.engine.kv.pool_stats();
                let free = pool.total.saturating_sub(pool.in_use);
                (
                    std::cmp::Reverse(free),
                    s.batcher.waiting() + s.running_count(),
                )
            })
    }

    /// Tick replica `i`'s open breaker; on half-open, record the probe.
    fn tick_breaker(&mut self, i: usize) -> bool {
        if self.health[i].tick() {
            self.engines[i].1.metrics.record_breaker_probe();
            self.engines[i].1.metrics.record_health_transition();
            trace::instant(
                "breaker_half_open",
                "router",
                None,
                &[("replica", i.to_string())],
            );
            log::info!(
                "replica {i} [{}]: breaker half-open, probing",
                self.engines[i].0
            );
            true
        } else {
            false
        }
    }

    /// A suspicion strike against replica `i`; returns true when it
    /// escalated past the threshold (caller must fail the replica over).
    fn strike(&mut self, i: usize, why: &str) -> bool {
        let before = self.health[i].state();
        let escalated = self.health[i].strike();
        if self.health[i].state() != before {
            self.engines[i].1.metrics.record_health_transition();
            trace::instant(
                "health",
                "router",
                None,
                &[
                    ("replica", i.to_string()),
                    ("from", format!("{before:?}")),
                    ("to", format!("{:?}", self.health[i].state())),
                    ("why", why.to_string()),
                ],
            );
            log::warn!(
                "replica {i} [{}]: {:?} -> {:?} ({why})",
                self.engines[i].0,
                before,
                self.health[i].state()
            );
        }
        escalated
    }

    /// Route to the best *routable* replica serving `mode`: free KV
    /// blocks are the primary key (the real admission bottleneck — a
    /// replica with a deep queue but an empty pool is still the wrong
    /// place for a new prompt), queued+running load breaks ties.
    /// Quarantined replicas receive nothing; when every replica of the
    /// mode is quarantined the request is load-shed with an honest
    /// "overloaded" error (sustained traffic still drives the breaker
    /// countdown, so a healed replica can half-open and take a later
    /// request as its probe). A tensor-parallel engine counts as *one*
    /// replica: its shards advance lock-step behind one scheduler, so
    /// its pool/load gauges already describe the whole group.
    pub fn route(&mut self, mode: &str, req: Request) -> crate::Result<()> {
        let Some(idxs) = self.by_mode.get(mode).cloned() else {
            anyhow::bail!("no engine for mode '{mode}'");
        };
        let routable: Vec<usize> = idxs
            .iter()
            .copied()
            .filter(|&i| self.health[i].is_routable())
            .collect();
        let pick = self.pick_among(&routable).or_else(|| {
            // every replica quarantined: drive the breaker countdown so
            // a healed replica can half-open and probe with this request
            let mut opened = None;
            for &i in &idxs {
                if self.tick_breaker(i) && opened.is_none() {
                    opened = Some(i);
                }
            }
            opened
        });
        let Some(idx) = pick else {
            if let Some(&i0) = idxs.first() {
                self.engines[i0].1.metrics.record_shed();
            }
            trace::instant(
                "shed",
                "router",
                Some(req.id),
                &[("mode", mode.to_string())],
            );
            anyhow::bail!(
                "overloaded: all {} replica(s) of mode '{mode}' are broken",
                idxs.len()
            );
        };
        self.assignments.insert(req.id, idx);
        self.engines[idx].1.submit_request(req);
        Ok(())
    }

    /// Step every live engine once, bracketed by the replica fault
    /// marker (`faults::set_replica`) so `replica=K` chaos plans hit
    /// exactly one engine. A replica whose step fails is quarantined
    /// and failed over — its siblings keep stepping; the router itself
    /// never errors. Returns tokens produced across the fleet.
    fn step_engines(&mut self) -> usize {
        for i in 0..self.engines.len() {
            self.tick_breaker(i);
        }
        let mut produced = 0;
        let mut stepped: Vec<(usize, f64)> = Vec::new();
        let mut broken: Vec<(usize, String)> = Vec::new();
        for i in 0..self.engines.len() {
            if self.health[i].is_quarantined() || !self.engines[i].1.has_work() {
                continue;
            }
            let floor_before = self.engines[i].1.metrics.ladder_floor_errors;
            crate::runtime::faults::set_replica(Some(i));
            let t0 = std::time::Instant::now();
            let res = self.engines[i].1.step();
            crate::runtime::faults::set_replica(None);
            match res {
                Ok(n) => {
                    produced += n;
                    stepped.push((i, t0.elapsed().as_secs_f64()));
                    let floor_delta =
                        self.engines[i].1.metrics.ladder_floor_errors - floor_before;
                    for _ in 0..floor_delta {
                        if self.strike(i, "ladder-floor error") {
                            broken.push((i, "ladder-floor errors".into()));
                            break;
                        }
                    }
                }
                Err(e) => {
                    let kind = if crate::runtime::faults::is_replica_down(&e) {
                        "chaos kill"
                    } else {
                        "engine error"
                    };
                    log::error!(
                        "replica {i} [{}]: step failed ({kind}): {e:#}",
                        self.engines[i].0
                    );
                    broken.push((i, format!("{kind}: {e:#}")));
                }
            }
        }
        // step-latency outliers: a replica far over its siblings' median
        // this round earns a strike (needs >= 3 stepped replicas for the
        // median to mean anything)
        if stepped.len() >= 3 {
            let mut lat: Vec<f64> = stepped.iter().map(|&(_, d)| d).collect();
            lat.sort_by(f64::total_cmp);
            let median = lat[lat.len() / 2];
            for &(i, d) in &stepped {
                if d > LATENCY_OUTLIER_FLOOR
                    && d > median * LATENCY_OUTLIER_FACTOR
                    && !broken.iter().any(|(b, _)| *b == i)
                    && self.strike(i, "step-latency outlier")
                {
                    broken.push((i, "step-latency outliers".into()));
                }
            }
        }
        // clean steps feed Suspect clearing and half-open probes
        for &(i, _) in &stepped {
            if broken.iter().any(|(b, _)| *b == i) {
                continue;
            }
            let before = self.health[i].state();
            if self.health[i].note_ok() {
                trace::instant(
                    "breaker_close",
                    "router",
                    None,
                    &[("replica", i.to_string())],
                );
                log::info!(
                    "replica {i} [{}]: probe succeeded, breaker closed",
                    self.engines[i].0
                );
            }
            if self.health[i].state() != before {
                self.engines[i].1.metrics.record_health_transition();
            }
        }
        for (i, why) in broken {
            self.fail_over(i, &why);
        }
        produced
    }

    /// Quarantine replica `src` (breaker opens) and migrate everything
    /// it holds: queued requests and running/preempted sequences move
    /// to the least-loaded healthy sibling, reconstructed there via the
    /// paged `prompt ++ generated` resume path. The source pool's
    /// bookkeeping — lanes, preemption-donated prefix-cache holds — is
    /// settled exactly once by `Scheduler::evacuate`; requests keep
    /// their original `submitted` instant so age-ordered admission and
    /// deadline enforcement carry over unchanged. With no healthy
    /// sibling the work is load-shed honestly with "overloaded" —
    /// never silently dropped.
    fn fail_over(&mut self, src: usize, why: &str) {
        let mode = self.engines[src].0.clone();
        let before = self.health[src].state();
        let reopen_in = self.health[src].trip();
        self.engines[src].1.metrics.record_breaker_open();
        if self.health[src].state() != before {
            self.engines[src].1.metrics.record_health_transition();
        }
        let (fresh, resumes) = self.engines[src].1.evacuate();
        let migrated = fresh.len() + resumes.len();
        trace::instant(
            "failover",
            "router",
            None,
            &[
                ("replica", src.to_string()),
                ("mode", mode.clone()),
                ("migrated", migrated.to_string()),
                ("probe_in", reopen_in.to_string()),
                ("why", why.to_string()),
            ],
        );
        log::warn!(
            "replica {src} [{mode}]: broken ({why}); breaker open, probe in \
             {reopen_in} step(s); migrating {} queued + {} in-flight",
            fresh.len(),
            resumes.len()
        );
        let siblings: Vec<usize> = self
            .by_mode
            .get(&mode)
            .map(|v| {
                v.iter()
                    .copied()
                    .filter(|&i| i != src && self.health[i].is_routable())
                    .collect()
            })
            .unwrap_or_default();
        if siblings.is_empty() {
            // every replica of the mode is broken: shed honestly
            for req in fresh {
                self.assignments.remove(&req.id);
                self.engines[src].1.metrics.record_shed();
                self.engines[src].1.metrics.record_rejected();
                self.orphans.push(Response::rejection(
                    req.id,
                    req.echo_text,
                    "overloaded".into(),
                ));
            }
            for run in resumes {
                self.assignments.remove(&run.request.id);
                self.engines[src].1.metrics.record_shed();
                let resp = run.into_response(FinishReason::Error("overloaded".into()));
                self.engines[src].1.metrics.record_finished(&resp);
                self.orphans.push(resp);
            }
            self.engines[src].1.metrics.record_failover(migrated, 0);
            return;
        }
        let mut reprefill = 0usize;
        for req in fresh {
            let dst = self.pick_among(&siblings).unwrap();
            self.assignments.insert(req.id, dst);
            // straight into the batcher: the fleet already accepted this
            // work, so a drain-mode destination must still finish it
            // rather than reject it as a new submission
            self.engines[dst].1.batcher.submit_request(req);
        }
        for run in resumes {
            let dst = self.pick_among(&siblings).unwrap();
            reprefill += run.resume_tokens().len();
            self.assignments.insert(run.request.id, dst);
            self.engines[dst].1.batcher.push_resume(run);
        }
        self.engines[src].1.metrics.record_failover(migrated, reprefill);
    }

    /// Drain finished responses from every engine plus the router's own
    /// load-shed orphans, retiring their routing assignments.
    fn collect_finished(&mut self) -> Vec<Response> {
        let mut out = std::mem::take(&mut self.orphans);
        for (_, sched) in self.engines.iter_mut() {
            out.extend(sched.take_finished());
        }
        for r in &out {
            self.assignments.remove(&r.id);
        }
        out
    }

    /// Step every engine once; collects finished responses. Never
    /// errors while any replica can still make progress — a failing
    /// replica is quarantined and its work migrated (`fail_over`), so
    /// one dead engine can no longer kill every other engine's traffic.
    pub fn step_all(&mut self) -> crate::Result<Vec<Response>> {
        self.step_engines();
        Ok(self.collect_finished())
    }

    pub fn has_work(&self) -> bool {
        !self.orphans.is_empty()
            || self
                .engines
                .iter()
                .enumerate()
                .any(|(i, (_, s))| s.has_work() && !self.health[i].is_quarantined())
    }

    pub fn run_to_completion(&mut self) -> crate::Result<Vec<Response>> {
        let mut all = Vec::new();
        while self.has_work() {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    pub fn scheduler_mut(&mut self, mode: &str) -> Option<&mut Scheduler> {
        let idx = *self.by_mode.get(mode)?.first()?;
        Some(&mut self.engines[idx].1)
    }

    pub fn pending_assignments(&self) -> usize {
        self.assignments.len()
    }

    /// Default mode when a request names none: the alphabetically first
    /// (stable, independent of registration order).
    pub fn default_mode(&self) -> Option<String> {
        self.modes().into_iter().next()
    }

    /// Cancel a routed request wherever it currently lives — after a
    /// failover migration the assignment tracks the *destination*
    /// replica, so the cancel releases that pool's blocks and slot, not
    /// the dead source's (whose holds `evacuate` already settled).
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(idx) = self.assignments.remove(&id) {
            return self.engines[idx].1.cancel(id);
        }
        false
    }

    pub fn cancel_all(&mut self) {
        for (_, sched) in self.engines.iter_mut() {
            sched.cancel_all();
        }
        self.assignments.clear();
    }

    pub fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        let mut out = Vec::new();
        for (_, sched) in self.engines.iter_mut() {
            out.extend(sched.take_token_events());
        }
        out
    }

    /// Queued + running requests across every engine.
    pub fn load(&self) -> usize {
        self.engines
            .iter()
            .map(|(_, s)| s.batcher.waiting() + s.running_count())
            .sum()
    }
}

impl ServeBackend for Router {
    fn submit(&mut self, mode: Option<&str>, req: Request) -> Result<(), String> {
        let mode = match mode {
            Some(m) => m.to_string(),
            None => self
                .default_mode()
                .ok_or_else(|| "router has no engines".to_string())?,
        };
        self.route(&mode, req).map_err(|e| format!("{e:#}"))
    }

    fn has_work(&self) -> bool {
        Router::has_work(self)
    }

    fn step(&mut self) -> crate::Result<usize> {
        Ok(self.step_engines())
    }

    fn take_finished(&mut self) -> Vec<Response> {
        self.collect_finished()
    }

    fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        Router::take_token_events(self)
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        Router::cancel(self, id)
    }

    fn cancel_all(&mut self) {
        Router::cancel_all(self)
    }

    fn drain(&mut self) {
        for (_, sched) in self.engines.iter_mut() {
            sched.drain();
        }
    }

    fn record_drain(&mut self, seconds: f64) {
        if let Some((_, s)) = self.engines.first_mut() {
            // process-level gauge; by convention it lives on engine 0
            s.metrics.record_drain(seconds);
        }
    }

    fn load(&self) -> usize {
        Router::load(self)
    }

    fn vocab(&self) -> usize {
        self.engines
            .first()
            .map(|(_, s)| s.engine.session.manifest.vocab)
            .unwrap_or(0)
    }

    fn record_rejected(&mut self) {
        if let Some((_, s)) = self.engines.first_mut() {
            // process-level counter; by convention it lives on engine 0
            s.metrics.record_rejected();
        }
    }

    fn log_metrics(&self) {
        for (i, (mode, sched)) in self.engines.iter().enumerate() {
            log_scheduler_metrics(&format!("serve[{mode}#{i}]"), sched);
        }
    }

    /// Per-replica exposition: the fleet's text is the concatenation of
    /// every replica's labeled render (plus a health gauge per replica),
    /// so any Prometheus server can aggregate across the labels.
    fn metrics_text(&self) -> String {
        let mut out = String::new();
        for (i, (mode, sched)) in self.engines.iter().enumerate() {
            let labels = [
                ("mode", mode.clone()),
                ("replica", i.to_string()),
                ("shards", sched.engine.n_shards().to_string()),
            ];
            telemetry::sample(
                &mut out,
                "cushion_replica_routable",
                &labels,
                if self.health[i].is_routable() { 1.0 } else { 0.0 },
            );
            out.push_str(&telemetry::render_metrics(&sched.metrics, &labels));
        }
        out
    }

    fn floor_errors(&self) -> usize {
        self.engines
            .iter()
            .map(|(_, s)| s.metrics.ladder_floor_errors)
            .sum()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Engine, Scheduler};
    use crate::quant::scheme::Scheme;
    use crate::testkit::tiny::TinyCfg;

    fn sched() -> Scheduler {
        Scheduler::new(
            Engine::new(TinyCfg::default().session().unwrap(), Scheme::fp())
                .unwrap(),
        )
    }

    fn prompt(s: &Scheduler) -> Vec<i32> {
        s.engine.session.corpus.split("heldout").unwrap().seq(0)[..4].to_vec()
    }

    #[test]
    fn block_starved_replica_stops_receiving_work() {
        let mut r = Router::new();
        r.add_engine("fp", sched());
        r.add_engine("fp", sched());
        // occupy replica 0's pool directly: fewer free blocks than the
        // idle replica 1, while both queues stay empty — under pure
        // load-based dispatch the replicas would look identical
        {
            let kv = &mut r.engines[0].1.engine.kv;
            let mut id = 100u64;
            while kv.alloc(id, 4).is_some() {
                id += 1;
            }
            assert!(
                kv.pool_stats().in_use > 0,
                "allocation must consume blocks"
            );
        }
        let free = |r: &Router, i: usize| {
            let p = r.engines[i].1.engine.kv.pool_stats();
            p.total - p.in_use
        };
        assert!(free(&r, 0) < free(&r, 1), "replica 0 must be starved");
        let p = prompt(&r.engines[1].1);
        for id in 0..3u64 {
            r.route("fp", Request::new(id, p.clone(), 2)).unwrap();
        }
        assert_eq!(
            r.engines[0].1.batcher.waiting(),
            0,
            "block-starved replica must stop receiving work"
        );
        assert_eq!(r.engines[1].1.batcher.waiting(), 3);
    }

    #[test]
    fn equal_pools_tie_break_on_load() {
        let mut r = Router::new();
        r.add_engine("fp", sched());
        r.add_engine("fp", sched());
        let p = prompt(&r.engines[0].1);
        r.route("fp", Request::new(1, p.clone(), 2)).unwrap();
        r.route("fp", Request::new(2, p, 2)).unwrap();
        assert_eq!(r.engines[0].1.batcher.waiting(), 1, "load breaks the tie");
        assert_eq!(r.engines[1].1.batcher.waiting(), 1);
    }

    #[test]
    fn health_machine_walks_healthy_suspect_broken_halfopen() {
        let mut h = ReplicaHealth::new(7);
        assert_eq!(h.state(), Health::Healthy);
        assert!(h.is_routable());
        // strikes: Healthy -> Suspect, escalation at the threshold
        assert!(!h.strike());
        assert_eq!(h.state(), Health::Suspect);
        assert!(h.is_routable(), "suspect still serves");
        assert!(!h.strike());
        assert!(h.strike(), "third strike escalates");
        // the caller breaks it
        let probe_in = h.trip();
        assert_eq!(h.state(), Health::Broken);
        assert!(!h.is_routable());
        assert!(
            (BREAKER_BASE_STEPS..=BREAKER_BASE_STEPS + BREAKER_BASE_STEPS / 2)
                .contains(&probe_in),
            "first open interval near the base: {probe_in}"
        );
        // countdown to half-open
        for _ in 0..probe_in - 1 {
            assert!(!h.tick());
            assert_eq!(h.state(), Health::Broken);
        }
        assert!(h.tick(), "countdown exhausts into the probe");
        assert_eq!(h.state(), Health::HalfOpen);
        assert!(h.is_routable());
        // enough clean probe steps close the breaker
        for _ in 0..PROBE_OK_STEPS - 1 {
            assert!(!h.note_ok());
        }
        assert!(h.note_ok(), "breaker closes");
        assert_eq!(h.state(), Health::Healthy);
    }

    #[test]
    fn breaker_backoff_doubles_and_caps() {
        let mut h = ReplicaHealth::new(42);
        let mut prev = 0u64;
        for round in 0..8 {
            let open = h.trip();
            assert!(
                open <= BREAKER_MAX_STEPS + BREAKER_MAX_STEPS / 2,
                "round {round}: open interval {open} beyond the cap"
            );
            if round > 0 && prev < BREAKER_MAX_STEPS / 2 {
                assert!(open > prev, "round {round}: backoff must grow");
            }
            prev = open;
        }
        // a closed breaker resets the backoff
        while h.state() == Health::Broken {
            h.tick();
        }
        for _ in 0..PROBE_OK_STEPS {
            h.note_ok();
        }
        assert_eq!(h.state(), Health::Healthy);
        let reopened = h.trip();
        assert!(
            reopened <= BREAKER_BASE_STEPS + BREAKER_BASE_STEPS / 2,
            "closed breaker resets backoff: {reopened}"
        );
    }

    #[test]
    fn suspect_clears_after_clean_steps() {
        let mut h = ReplicaHealth::new(3);
        h.strike();
        assert_eq!(h.state(), Health::Suspect);
        for _ in 0..SUSPECT_CLEAR_OKS - 1 {
            h.note_ok();
            assert_eq!(h.state(), Health::Suspect);
        }
        h.note_ok();
        assert_eq!(h.state(), Health::Healthy);
        // and the strike counter reset with it
        assert!(!h.strike());
        assert!(!h.strike());
        assert!(h.strike(), "full threshold again after clearing");
    }

    #[test]
    fn seeded_probe_schedule_is_deterministic() {
        let run = |seed: u64| {
            let mut h = ReplicaHealth::new(seed);
            (0..5).map(|_| h.trip()).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12), "different seeds, different jitter");
    }

    #[test]
    fn quarantined_replica_receives_no_routes() {
        let mut r = Router::new();
        r.add_engine("fp", sched());
        r.add_engine("fp", sched());
        let p = prompt(&r.engines[0].1);
        r.health[0].trip();
        for id in 0..3u64 {
            r.route("fp", Request::new(id, p.clone(), 2)).unwrap();
        }
        assert_eq!(r.engines[0].1.batcher.waiting(), 0, "quarantined");
        assert_eq!(r.engines[1].1.batcher.waiting(), 3);
        // both broken: honest shed, not a panic or a silent drop
        r.health[1].trip();
        let err = r
            .route("fp", Request::new(9, p, 2))
            .unwrap_err()
            .to_string();
        assert!(err.contains("overloaded"), "sheds honestly: {err}");
    }
}
