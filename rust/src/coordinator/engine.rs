//! The serving engine: prefill/decode execution against one variant.
//!
//! One engine = one quantization scheme (the router owns several). The KV
//! cache is threaded functionally through each graph call — the graph
//! returns the updated cache as output 0 — and is held as a *device*
//! value between steps. Three mechanisms keep the steady-state decode
//! step's host traffic at a few kilobytes (see README "Serving hot
//! path" and benches/perf_hotpath.rs for the budget):
//!
//! 1. **Resident invariants** (PR 1): weights, ranges, inv_smooth, the
//!    cushion prefix KV, and the scheme's level scalars live in the
//!    session's ResidentPool, uploaded once per configuration.
//! 2. **Device-side token selection**: when the variant ships
//!    `decode_sampled_*` / `prefill_sampled_*` graphs, greedy argmax
//!    runs in-graph and only `[B]` i32 token ids are fetched per step
//!    instead of `[B, vocab]` f32 logits (`Outputs::host_i32`).
//! 3. **Donated cache residency**: a `runtime::split::TupleSplitter`
//!    per output signature decomposes the result tuple into per-output
//!    *device* buffers, so the cache element never materializes as a
//!    host literal between steps (xla_extension 0.5.1 returns root
//!    tuples as one buffer and exposes no aliasing config; the splitter
//!    replaces the seed's fetch+re-upload with a device-side copy).
//!
//! Prefill is additionally **bucketed**: the engine picks the smallest
//! `prefill_sampled_*_b<n>` bucket >= the prompt length (manifest
//! `prefill_buckets`), so short prompts neither pad to `seq_len` nor pay
//! a full-width forward.
//!
//! Since the paged-KV refactor (coordinator::kvpool) the logical cache
//! is a block pool: the cushion prefix is stored once in a pinned shared
//! block run and sequences hold refcounted block tables. The contiguous
//! `[L, 2, B, Hkv, CAP, dh]` tensor the graphs consume is a per-batch
//! *gather view* of that pool, materialized at engine setup and then
//! carried functionally through the graphs exactly as before — so the
//! device-residency story (and the per-step transfer budget) is
//! unchanged. Execution modes:
//!
//! * default — device-resident gather view; the pool carries cushion
//!   contents plus block accounting (sharing/eviction/preemption math).
//! * `set_host_roundtrip(true)` — the seed's host round-trip, now also
//!   *mirroring* every written KV row back into the owning sequence's
//!   blocks, keeping pool contents authoritative (parity tests
//!   cross-check the gather view against the pool).
//! * `set_paged_attention(true)` — the native block-table path: decode
//!   and prefill run the `*_paged_*` interpreter graphs straight over
//!   the pool tensor + block tables, no contiguous view at all
//!   (hermetic end-to-end paging tests).
//!
//! All paths are independently degradable: missing sampled artifacts
//! fall back to the logits graphs + host argmax, a failed splitter falls
//! back to host materialization, and `cache_host()` fetches the
//! contiguous view for inspection in any mode.

use std::rc::Rc;

use crate::data::PAD;
use crate::eval::perplexity::{argmax, argmax_rows};
use crate::model::forward::ModelSpec;
use crate::model::session::Session;
use crate::quant::scheme::Scheme;
use crate::runtime::interp::InterpProgram;
use crate::runtime::literalx::{self, HostValue, IntTensor, OutValue, Value};
use crate::runtime::split::{OutSpec, TupleSplitter};
use crate::runtime::{DeviceBuf, DeviceGroup};
use crate::util::tensor::Tensor;

use super::kvpool::PagedKv;

/// Which pool mirror a cache store should perform (host-resident modes).
enum Mirror {
    /// A prefill wrote prompt positions of this slot.
    Prefill(usize),
    /// A decode step wrote one KV row per busy slot.
    Decode,
}

/// Tensor-parallel state (manifest `n_shards` > 1): the shard group and
/// the per-shard contiguous caches `[L, 2, B, Hkv/n, CAP, dh]`. The
/// caches are host-held — shard threads are the logical devices and
/// execute interpreter programs on host values directly, so the
/// host-transfer gauges stay honest (shard-to-shard traffic is metered
/// by `runtime::collective` instead). Written KV rows are mirrored into
/// the block pool per shard, so pool storage is per-shard along the
/// `Hkv` axis while block tables stay global.
struct ShardedState {
    group: DeviceGroup,
    caches: Vec<Tensor>,
}

pub struct Engine {
    pub session: Session,
    pub scheme: Scheme,
    pub kv: PagedKv,
    /// The contiguous per-batch gather view [L, 2, B, Hkv, CAP, dh]:
    /// host only at init / after reset, a device value across
    /// prefill/decode steps (unused while `paged_attention` is on).
    cache: Value,
    /// Parity/debug knob: when set, the cache makes the seed's full
    /// host round-trip (fetch to f32, re-upload next step) per step,
    /// tuple splitting is bypassed, and written KV rows mirror into the
    /// block pool.
    host_roundtrip: bool,
    /// Native block-table execution (`prefill_paged_*`/`decode_paged_*`
    /// over the pool tensor) — the hermetic true-paging path. Set it on
    /// a fresh engine, before any sequence runs.
    paged_attention: bool,
    /// Pool-size override (None = manifest/derived), kept for
    /// `reset_cache`.
    pool_blocks: Option<usize>,
    /// Use the `*_sampled_*` graphs (in-graph argmax) when present.
    device_sampling: bool,
    /// Use bucketed prefill graphs when present (off = full seq_len).
    prefill_bucketing: bool,
    /// Engine-invariant scalar operands, uploaded once per engine. The
    /// cushion-length scalar lives in the session's pool (keyed with the
    /// prefix KV) so the (KV, len) pair is always coherent.
    act_levels_buf: Rc<DeviceBuf>,
    kv_levels_buf: Rc<DeviceBuf>,
    suffix: String,
    prefill_graph: String,
    decode_graph: String,
    /// Present iff the artifact exists on disk.
    decode_sampled_graph: Option<String>,
    /// Ascending bucket lengths whose `prefill_sampled_*_b<n>` artifact
    /// exists (empty = no sampled prefill available).
    sampled_buckets: Vec<usize>,
    /// On-device tuple splitters, one per output signature the engine
    /// executes. `None` = that signature degrades to host
    /// materialization (logged once at construction).
    split_decode: Option<TupleSplitter>,
    split_prefill: Option<TupleSplitter>,
    split_decode_sampled: Option<TupleSplitter>,
    split_prefill_sampled: Option<TupleSplitter>,
    /// Tensor-parallel shard group. `None` = unsharded — every
    /// pre-existing path is untouched.
    shards: Option<ShardedState>,
}

impl Engine {
    pub fn new(session: Session, scheme: Scheme) -> crate::Result<Self> {
        let m = &session.manifest;
        let cushion_len = session.cushion().map(|c| c.len).unwrap_or(0);
        // the cushion KV is written once into the pool's pinned shared
        // block run; the contiguous view below is gathered from it
        let kv = PagedKv::for_manifest(
            m,
            session.cushion().map(|c| &c.kv),
            cushion_len,
            None,
        );
        let cache = kv.gather_view();
        let client = session.registry.client();
        let act_levels_buf = Rc::new(client.upload(&Tensor::scalar(scheme.act_levels()))?);
        let kv_levels_buf = Rc::new(client.upload(&Tensor::scalar(scheme.kv_levels()))?);
        let suffix = scheme.gran.graph_suffix().to_string();

        // optional-variant probes go through has_upgrade so a partially
        // regenerated artifact dir stays on the compiled base graphs
        // (registry.rs docs) — on the reference backend everything
        // resolves to the interpreter and the sampled paths light up
        let decode_sampled = format!("decode_sampled_{suffix}");
        let decode_sampled_graph = session
            .registry
            .has_upgrade(&decode_sampled, &format!("decode_{suffix}"))
            .then_some(decode_sampled);
        let prefill_base = format!("prefill_{suffix}");
        let sampled_buckets: Vec<usize> = m
            .prefill_buckets
            .iter()
            .copied()
            .filter(|b| {
                session.registry.has_upgrade(
                    &format!("prefill_sampled_{suffix}_b{b}"),
                    &prefill_base,
                )
            })
            .collect();

        // splitters keyed by output signature (shared across buckets)
        let cache_dims = [
            m.n_layers, 2, m.serve_batch, m.n_kv_heads, m.cache_cap, m.d_head,
        ];
        let b = m.serve_batch;
        let v = m.vocab;
        // splitters exist to keep PJRT root tuples on device; the
        // reference interpreter's outputs are already per-element, so on
        // that backend none are built (and none warned about)
        let splitters_apply = client.compiles_artifacts();
        let mk = |spec: &[OutSpec], what: &str| -> Option<TupleSplitter> {
            if !splitters_apply {
                return None;
            }
            match TupleSplitter::new(client, spec) {
                Ok(s) => Some(s),
                Err(e) => {
                    log::warn!(
                        "tuple splitter for {what} unavailable ({e:#}); \
                         that path will materialize outputs on host"
                    );
                    None
                }
            }
        };
        let split_decode = mk(
            &[OutSpec::f32(&cache_dims), OutSpec::f32(&[b, v])],
            "decode",
        );
        let split_prefill = mk(
            &[OutSpec::f32(&cache_dims), OutSpec::f32(&[v])],
            "prefill",
        );
        let split_decode_sampled = decode_sampled_graph.is_some().then(|| {
            mk(
                &[
                    OutSpec::f32(&cache_dims),
                    OutSpec::i32(&[b]),
                    OutSpec::f32(&[b]),
                ],
                "decode_sampled",
            )
        }).flatten();
        let split_prefill_sampled = (!sampled_buckets.is_empty()).then(|| {
            mk(
                &[
                    OutSpec::f32(&cache_dims),
                    OutSpec::i32(&[]),
                    OutSpec::f32(&[]),
                ],
                "prefill_sampled",
            )
        }).flatten();

        // tensor-parallel group (manifest n_shards / --shards): resolve
        // the per-shard program names through the registry now, so a
        // missing interpreter or bad geometry fails at construction
        let n_shards = m.n_shards;
        let shards = if n_shards > 1 {
            for k in 0..n_shards {
                for op in ["prefill", "decode"] {
                    let name = format!("{op}_{suffix}_s{k}of{n_shards}");
                    anyhow::ensure!(
                        session.registry.has(&name),
                        "sharded engine: graph '{name}' unresolvable \
                         (sharded execution runs on the reference \
                         interpreter)"
                    );
                }
            }
            let caches = (0..n_shards)
                .map(|k| kv.gather_view_shard(k, n_shards))
                .collect::<crate::Result<Vec<_>>>()?;
            Some(ShardedState { group: DeviceGroup::new(n_shards), caches })
        } else {
            None
        };

        Ok(Self {
            shards,
            prefill_graph: format!("prefill_{suffix}"),
            decode_graph: format!("decode_{suffix}"),
            device_sampling: decode_sampled_graph.is_some()
                || !sampled_buckets.is_empty(),
            decode_sampled_graph,
            sampled_buckets,
            split_decode,
            split_prefill,
            split_decode_sampled,
            split_prefill_sampled,
            suffix,
            kv,
            cache: Value::Host(HostValue::F32(cache)),
            host_roundtrip: false,
            paged_attention: false,
            pool_blocks: None,
            prefill_bucketing: true,
            act_levels_buf,
            kv_levels_buf,
            scheme,
            session,
        })
    }

    /// Rebuild the pool + view with the session's (possibly new)
    /// cushion. Drops every live sequence and the prefix cache.
    pub fn reset_cache(&mut self) {
        let m = &self.session.manifest;
        let cushion_len = self.session.cushion().map(|c| c.len).unwrap_or(0);
        crate::runtime::trace::instant("reset_cache", "engine", None, &[
            ("cushion_len", cushion_len.to_string()),
            ("blocks", self.pool_blocks.map_or("auto".into(), |b| b.to_string())),
        ]);
        self.kv = PagedKv::for_manifest(
            m,
            self.session.cushion().map(|c| &c.kv),
            cushion_len,
            self.pool_blocks,
        );
        self.cache = Value::Host(HostValue::F32(self.kv.gather_view()));
        if let Some(sh) = &mut self.shards {
            let n = sh.group.n_shards();
            sh.caches = (0..n)
                .map(|k| self.kv.gather_view_shard(k, n))
                .collect::<crate::Result<_>>()
                .expect("shard geometry validated at construction");
        }
    }

    /// Shard count of this engine (1 = unsharded).
    pub fn n_shards(&self) -> usize {
        self.shards.as_ref().map_or(1, |s| s.group.n_shards())
    }

    /// Override the KV pool size (blocks) and rebuild — the pool-churn /
    /// preemption harness: a pool smaller than aggregate demand makes
    /// the scheduler preempt instead of reject. Floored so one lane can
    /// always reach `cache_cap`.
    pub fn set_pool_blocks(&mut self, n_blocks: usize) {
        self.pool_blocks = Some(n_blocks);
        self.reset_cache();
    }

    /// Force the seed's per-step host round-trip of the cache (decode
    /// parity tests); the device-resident path is the default. Also
    /// mirrors written KV rows into the block pool, making pool contents
    /// authoritative.
    pub fn set_host_roundtrip(&mut self, on: bool) {
        if on && self.shards.is_some() {
            log::warn!(
                "host round-trip is a no-op on a sharded engine: per-shard \
                 caches are host-held and pool-mirrored already"
            );
            return;
        }
        self.host_roundtrip = on;
    }

    /// Execute via the native block-table graphs (`*_paged_*`) over the
    /// pool tensor — true paging end-to-end, no contiguous view. A
    /// hermetic/reference-backend mode: enable it on a fresh engine
    /// before any sequence runs (pool contents must be authoritative
    /// from the start).
    pub fn set_paged_attention(&mut self, on: bool) {
        if on && self.shards.is_some() {
            log::warn!(
                "native paged attention requires n_shards == 1; ignoring"
            );
            return;
        }
        self.paged_attention = on;
    }

    pub fn paged_attention(&self) -> bool {
        self.paged_attention
    }

    /// Toggle in-graph token selection (effective only when the variant
    /// ships `*_sampled_*` artifacts). Off = fetch logits, argmax on
    /// host — the parity reference for the sampling tests.
    pub fn set_device_sampling(&mut self, on: bool) {
        self.device_sampling = on;
    }

    /// Whether the sampled decode graph is actually available.
    pub fn sampled_decode_available(&self) -> bool {
        self.decode_sampled_graph.is_some()
    }

    /// Bucket lengths with a sampled prefill artifact (ascending).
    pub fn sampled_prefill_buckets(&self) -> &[usize] {
        &self.sampled_buckets
    }

    /// Toggle bucketed prefill (off = always the full seq_len bucket;
    /// the bucket-boundary parity tests compare the two).
    pub fn set_prefill_bucketing(&mut self, on: bool) {
        self.prefill_bucketing = on;
    }

    pub fn cushion_len(&self) -> usize {
        self.session.cushion().map(|c| c.len).unwrap_or(0)
    }

    /// The cache operand for the next step. A `Device` cache is a cheap
    /// `Rc` clone; the `Host` form only exists right after init/reset
    /// (one copy). Cloning (rather than moving the cache out) keeps the
    /// engine retryable: a failed step leaves `self.cache` untouched.
    fn cache_arg(&self) -> Value {
        self.cache.clone()
    }

    /// Store the cache output of a step per the residency mode. In the
    /// host-round-trip mode the rows the graph just wrote are mirrored
    /// into the owning sequences' pool blocks (shared blocks excluded —
    /// their contents are identical by construction), so the pool stays
    /// the authoritative store.
    fn store_cache(&mut self, out: OutValue, mirror: Mirror) -> crate::Result<()> {
        if self.host_roundtrip {
            let t = out.to_tensor()?;
            match mirror {
                Mirror::Prefill(slot) => self.kv.scatter_prefill(&t, slot),
                Mirror::Decode => {
                    for slot in self.kv.busy_slots() {
                        self.kv.scatter_decode_row(&t, slot);
                    }
                }
            }
            self.cache = Value::Host(HostValue::F32(t));
        } else {
            self.cache = out.into_value(self.session.registry.client())?;
        }
        Ok(())
    }

    /// The plan for one prefill: (graph name, padded length, sampled?).
    /// Sampled + bucketed when artifacts allow, else the legacy
    /// full-length logits graph.
    fn prefill_plan(&self, prompt_len: usize) -> (String, usize, bool) {
        let seq_len = self.session.manifest.seq_len;
        if self.device_sampling {
            let candidates: &[usize] = if self.prefill_bucketing {
                &self.sampled_buckets
            } else {
                // full-length only: the last bucket is seq_len by
                // construction when bucketed artifacts exist
                match self.sampled_buckets.last() {
                    Some(last) if *last == seq_len => {
                        std::slice::from_ref(self.sampled_buckets.last().unwrap())
                    }
                    _ => &[],
                }
            };
            if let Some(&b) = candidates.iter().find(|&&b| b >= prompt_len) {
                return (
                    format!("prefill_sampled_{}_b{b}", self.suffix),
                    b,
                    true,
                );
            }
        }
        (self.prefill_graph.clone(), seq_len, false)
    }

    /// Prefill `tokens` into `slot`; returns the first generated token.
    /// When the slot holds an allocated sequence its full prompt blocks
    /// are published into the prefix cache afterwards (and, in mirrored
    /// modes, its block contents are brought up to date first).
    pub fn prefill(&mut self, slot: usize, tokens: &[i32]) -> crate::Result<i32> {
        let m = &self.session.manifest;
        anyhow::ensure!(tokens.len() <= m.seq_len, "prompt too long");
        if self.kv.request_of(slot).is_some() {
            anyhow::ensure!(
                self.kv.tok_len(slot) == tokens.len(),
                "prefill length {} != allocated prompt length {}",
                tokens.len(),
                self.kv.tok_len(slot)
            );
        }
        if self.shards.is_some() {
            return self.prefill_sharded(slot, tokens);
        }
        if self.paged_attention {
            return self.prefill_paged(slot, tokens);
        }
        let (graph, bucket, sampled) = self.prefill_plan(tokens.len());
        let mut padded = tokens.to_vec();
        padded.resize(bucket, PAD);
        let splitter = if self.host_roundtrip {
            None
        } else if sampled {
            self.split_prefill_sampled.as_ref()
        } else {
            self.split_prefill.as_ref()
        };
        let mut outs = self.session.run_values_split(
            &graph,
            vec![
                self.cache_arg(),
                self.session.prefix_kv_value()?,
                self.session.prefix_len_value()?,
                Value::scalar_i32(slot as i32),
                Value::Host(HostValue::I32(IntTensor::vec(padded))),
                Value::scalar_i32(tokens.len() as i32),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
            splitter,
        )?;
        let first = if sampled {
            anyhow::ensure!(outs.len() == 3, "prefill_sampled: expected 3 outputs");
            let ids = outs.host_i32(1)?;
            anyhow::ensure!(ids.data.len() == 1, "prefill_sampled: want 1 id");
            self.store_cache(outs.take(0)?, Mirror::Prefill(slot))?;
            ids.data[0]
        } else {
            anyhow::ensure!(outs.len() == 2, "prefill: expected 2 outputs");
            let logits = outs.host_f32(1)?;
            self.store_cache(outs.take(0)?, Mirror::Prefill(slot))?;
            argmax(&logits.data) as i32
        };
        self.kv.publish_prefix(slot);
        Ok(first)
    }

    /// Whether this engine can serve resumable chunked prefill: the
    /// default device-resident mode only (the host-round-trip,
    /// native-paged, and sharded paths keep their single-shot prefills),
    /// and the `prefill_chunk_<mode>` graph must resolve.
    pub fn supports_chunked_prefill(&self) -> bool {
        !self.host_roundtrip
            && !self.paged_attention
            && self.shards.is_none()
            && self
                .session
                .registry
                .has(&format!("prefill_chunk_{}", self.suffix))
    }

    /// Extend slot `slot`'s prompt prefix — `done` tokens already
    /// written by earlier chunks — by `chunk`. Returns
    /// `Some(first_token)` when this chunk completes the allocated
    /// prompt (the chunk's last-row logits seed decode, host-argmaxed),
    /// `None` while the prompt is still partial. The slot's prompt
    /// blocks are published into the prefix cache only once the final
    /// chunk lands — a partial prefix must never serve cache hits.
    pub fn prefill_chunk(&mut self, slot: usize, chunk: &[i32], done: usize)
                         -> crate::Result<Option<i32>> {
        anyhow::ensure!(!chunk.is_empty(), "prefill_chunk: empty chunk");
        anyhow::ensure!(
            self.supports_chunked_prefill(),
            "prefill_chunk: unsupported in this execution mode"
        );
        anyhow::ensure!(
            self.kv.request_of(slot).is_some(),
            "prefill_chunk: slot {slot} holds no allocated sequence"
        );
        let total = self.kv.tok_len(slot);
        anyhow::ensure!(
            done + chunk.len() <= total,
            "prefill_chunk: {done}+{} tokens exceed the allocated \
             prompt length {total}",
            chunk.len()
        );
        let mut outs = self.session.run_values_split(
            &format!("prefill_chunk_{}", self.suffix),
            vec![
                self.cache_arg(),
                self.session.prefix_kv_value()?,
                self.session.prefix_len_value()?,
                Value::scalar_i32(slot as i32),
                Value::Host(HostValue::I32(IntTensor::vec(chunk.to_vec()))),
                Value::scalar_i32(done as i32),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
            // same output signature as the non-sampled prefill graph
            self.split_prefill.as_ref(),
        )?;
        anyhow::ensure!(outs.len() == 2, "prefill_chunk: expected 2 outputs");
        let logits = outs.host_f32(1)?;
        self.store_cache(outs.take(0)?, Mirror::Prefill(slot))?;
        if done + chunk.len() == total {
            self.kv.publish_prefix(slot);
            Ok(Some(argmax(&logits.data) as i32))
        } else {
            Ok(None)
        }
    }

    /// Native-path prefill: the `prefill_paged_*` graph writes this
    /// sequence's prompt KV straight into its pool blocks via the block
    /// table (no contiguous view).
    fn prefill_paged(&mut self, slot: usize, tokens: &[i32]) -> crate::Result<i32> {
        let table = self.kv.table_i32(slot).ok_or_else(|| {
            anyhow::anyhow!("paged prefill needs an allocated sequence in slot {slot}")
        })?;
        let outs = self.session.run_values(
            &format!("prefill_paged_{}", self.suffix),
            vec![
                Value::Host(HostValue::F32(self.kv.pool_tensor())),
                Value::Host(HostValue::I32(table)),
                self.session.prefix_kv_value()?,
                self.session.prefix_len_value()?,
                Value::Host(HostValue::I32(IntTensor::vec(tokens.to_vec()))),
                Value::scalar_i32(tokens.len() as i32),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "prefill_paged: expected 2 outputs");
        let logits = outs.host_f32(1)?;
        let pool = outs.host_f32(0)?;
        self.kv.install_pool(&pool)?;
        self.kv.publish_prefix(slot);
        Ok(argmax(&logits.data) as i32)
    }

    /// Tensor-parallel prefill: every shard runs its
    /// `prefill_<mode>_s<k>of<n>` slice of the forward lock-step through
    /// the group's collective bus. Prompts pad to the smallest covering
    /// `prefill_buckets` entry like the unsharded plan (the shard
    /// programs are length-polymorphic); with bucketing off they pad to
    /// the full `seq_len`, which is what makes the written cache match
    /// the unsharded full-length graph bit-for-bit in the whole-cache
    /// parity tests. Pad rows past `tok_len` are never attended and are
    /// masked out of quant stats, so logits are bucket-invariant.
    /// Logits are replicated (post-gather math is identical on every
    /// shard); the per-shard caches are mirrored into the pool's shard
    /// of each block's `Hkv` axis.
    fn prefill_sharded(&mut self, slot: usize, tokens: &[i32]) -> crate::Result<i32> {
        let n = self.n_shards();
        let spec_plain: ModelSpec = self
            .session
            .registry
            .interp_spec()
            .map(|rc| (*rc).clone())
            .ok_or_else(|| {
                anyhow::anyhow!("sharded execution needs the reference interpreter")
            })?;
        let weight_slices = self.session.shard_weight_slices(n)?;
        let weight_refs: Vec<&Vec<Tensor>> =
            weight_slices.iter().map(|r| r.as_ref()).collect();
        let prefix_slices = self.session.shard_prefix_slices(n)?;
        let prefix_refs: Vec<&Tensor> =
            prefix_slices.iter().map(|r| r.as_ref()).collect();
        let cushion_len = self.session.prefix_len();
        let ranges = self.session.ranges();
        let inv = self.session.inv_smooth();
        let (act_levels, kv_levels) =
            (self.scheme.act_levels(), self.scheme.kv_levels());
        let suffix = self.suffix.clone();
        let tok_len = tokens.len() as i32;
        let m = &self.session.manifest;
        let bucket = if self.prefill_bucketing {
            m.prefill_buckets
                .iter()
                .copied()
                .find(|&b| b >= tokens.len())
                .unwrap_or(m.seq_len)
        } else {
            m.seq_len
        };
        let mut padded = tokens.to_vec();
        padded.resize(bucket, PAD);

        let sh = self.shards.as_ref().expect("sharded path");
        let caches = &sh.caches;
        let mut results = sh.group.run(|k, bus| {
            crate::runtime::faults::inject_execute()?;
            let prog = InterpProgram::parse(
                Rc::new(spec_plain.clone()),
                &format!("prefill_{suffix}_s{k}of{n}"),
            )?;
            let mut args: Vec<HostValue> = weight_refs[k]
                .iter()
                .map(|t| HostValue::F32(t.clone()))
                .collect();
            args.push(HostValue::F32(caches[k].clone()));
            args.push(HostValue::F32(prefix_refs[k].clone()));
            args.push(HostValue::scalar_i32(cushion_len));
            args.push(HostValue::scalar_i32(slot as i32));
            args.push(HostValue::I32(IntTensor::vec(padded.clone())));
            args.push(HostValue::scalar_i32(tok_len));
            args.push(HostValue::F32(ranges.clone()));
            args.push(HostValue::F32(Tensor::scalar(act_levels)));
            args.push(HostValue::F32(Tensor::scalar(kv_levels)));
            args.push(HostValue::F32(inv.clone()));
            let mut outs = prog.execute_sharded(&args, bus)?;
            anyhow::ensure!(outs.len() == 2, "prefill shard: expected 2 outputs");
            let logits = outs.pop().unwrap();
            let cache = outs.pop().unwrap();
            match (cache, logits) {
                (HostValue::F32(c), HostValue::F32(l)) => Ok((c, l)),
                _ => anyhow::bail!("prefill shard: non-f32 outputs"),
            }
        })?;
        anyhow::ensure!(results.len() == n, "prefill shard: missing results");
        let first = argmax(&results[0].1.data) as i32;
        let sh = self.shards.as_mut().expect("sharded path");
        for (k, (c, _)) in results.drain(..).enumerate() {
            sh.caches[k] = c;
        }
        for k in 0..n {
            self.kv.scatter_prefill_shard(
                &self.shards.as_ref().unwrap().caches[k],
                slot,
                k,
                n,
            )?;
        }
        self.kv.publish_prefix(slot);
        Ok(first)
    }

    /// One decode step for all slots; `tokens[b]` is the last generated
    /// token of slot b (PAD for inactive slots). Returns next tokens [B].
    pub fn decode_step(&mut self, tokens: &[i32]) -> crate::Result<Vec<i32>> {
        let (serve_batch, v) =
            (self.session.manifest.serve_batch, self.session.manifest.vocab);
        anyhow::ensure!(tokens.len() == serve_batch);
        if self.shards.is_some() {
            return self.decode_step_sharded(tokens);
        }
        if self.host_roundtrip || self.paged_attention {
            // pool-writing modes: the block covering each busy slot's
            // write position (m_max + tok_len) must exist up front. The
            // scheduler already did this (with preemption on failure);
            // direct engine drivers get the default ample pool.
            for slot in self.kv.busy_slots() {
                anyhow::ensure!(
                    self.kv.ensure_append(slot),
                    "kv block pool exhausted growing slot {slot}"
                );
            }
        }
        if self.paged_attention {
            return self.decode_step_paged(tokens);
        }
        let sampled = self.device_sampling && self.decode_sampled_graph.is_some();
        let graph = match (&self.decode_sampled_graph, sampled) {
            (Some(g), true) => g.clone(),
            _ => self.decode_graph.clone(),
        };
        let splitter = if self.host_roundtrip {
            None
        } else if sampled {
            self.split_decode_sampled.as_ref()
        } else {
            self.split_decode.as_ref()
        };
        let mut outs = self.session.run_values_split(
            &graph,
            vec![
                self.cache_arg(),
                Value::Host(HostValue::I32(IntTensor::vec(self.kv.lens_i32()))),
                self.session.prefix_len_value()?,
                Value::Host(HostValue::I32(IntTensor::vec(tokens.to_vec()))),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
            splitter,
        )?;
        if sampled {
            anyhow::ensure!(outs.len() == 3, "decode_sampled: expected 3 outputs");
            let ids = outs.host_i32(1)?;
            anyhow::ensure!(
                ids.data.len() == serve_batch,
                "decode_sampled: want [B] ids"
            );
            self.store_cache(outs.take(0)?, Mirror::Decode)?;
            Ok(ids.data)
        } else {
            anyhow::ensure!(outs.len() == 2, "decode: expected 2 outputs");
            let logits = outs.host_f32(1)?;
            self.store_cache(outs.take(0)?, Mirror::Decode)?;
            Ok(argmax_rows(&logits.data, serve_batch, v))
        }
    }

    /// Native-path decode: the `decode_paged_*` graph reads and writes
    /// KV through the block tables over the pool tensor — true paged
    /// attention, no contiguous per-batch cache.
    fn decode_step_paged(&mut self, tokens: &[i32]) -> crate::Result<Vec<i32>> {
        let (serve_batch, v) =
            (self.session.manifest.serve_batch, self.session.manifest.vocab);
        let outs = self.session.run_values(
            &format!("decode_paged_{}", self.suffix),
            vec![
                Value::Host(HostValue::F32(self.kv.pool_tensor())),
                Value::Host(HostValue::I32(self.kv.tables_tensor())),
                Value::Host(HostValue::I32(IntTensor::vec(self.kv.lens_i32()))),
                self.session.prefix_len_value()?,
                Value::Host(HostValue::I32(IntTensor::vec(tokens.to_vec()))),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "decode_paged: expected 2 outputs");
        let logits = outs.host_f32(1)?;
        let pool = outs.host_f32(0)?;
        self.kv.install_pool(&pool)?;
        Ok(argmax_rows(&logits.data, serve_batch, v))
    }

    /// Tensor-parallel decode step: one `decode_<mode>_s<k>of<n>` run
    /// per shard, lock-step through the collective bus. Like the
    /// host-round-trip mode this is pool-writing — each shard's newly
    /// written KV row is mirrored into its slice of every busy slot's
    /// blocks before the scheduler advances lengths.
    fn decode_step_sharded(&mut self, tokens: &[i32]) -> crate::Result<Vec<i32>> {
        let (serve_batch, v) =
            (self.session.manifest.serve_batch, self.session.manifest.vocab);
        for slot in self.kv.busy_slots() {
            anyhow::ensure!(
                self.kv.ensure_append(slot),
                "kv block pool exhausted growing slot {slot}"
            );
        }
        let n = self.n_shards();
        let spec_plain: ModelSpec = self
            .session
            .registry
            .interp_spec()
            .map(|rc| (*rc).clone())
            .ok_or_else(|| {
                anyhow::anyhow!("sharded execution needs the reference interpreter")
            })?;
        let weight_slices = self.session.shard_weight_slices(n)?;
        let weight_refs: Vec<&Vec<Tensor>> =
            weight_slices.iter().map(|r| r.as_ref()).collect();
        let cushion_len = self.session.prefix_len();
        let lens = self.kv.lens_i32();
        let ranges = self.session.ranges();
        let inv = self.session.inv_smooth();
        let (act_levels, kv_levels) =
            (self.scheme.act_levels(), self.scheme.kv_levels());
        let suffix = self.suffix.clone();
        let toks = tokens.to_vec();

        let sh = self.shards.as_ref().expect("sharded path");
        let caches = &sh.caches;
        let mut results = sh.group.run(|k, bus| {
            crate::runtime::faults::inject_execute()?;
            let prog = InterpProgram::parse(
                Rc::new(spec_plain.clone()),
                &format!("decode_{suffix}_s{k}of{n}"),
            )?;
            let mut args: Vec<HostValue> = weight_refs[k]
                .iter()
                .map(|t| HostValue::F32(t.clone()))
                .collect();
            args.push(HostValue::F32(caches[k].clone()));
            args.push(HostValue::I32(IntTensor::vec(lens.clone())));
            args.push(HostValue::scalar_i32(cushion_len));
            args.push(HostValue::I32(IntTensor::vec(toks.clone())));
            args.push(HostValue::F32(ranges.clone()));
            args.push(HostValue::F32(Tensor::scalar(act_levels)));
            args.push(HostValue::F32(Tensor::scalar(kv_levels)));
            args.push(HostValue::F32(inv.clone()));
            let mut outs = prog.execute_sharded(&args, bus)?;
            anyhow::ensure!(outs.len() == 2, "decode shard: expected 2 outputs");
            let logits = outs.pop().unwrap();
            let cache = outs.pop().unwrap();
            match (cache, logits) {
                (HostValue::F32(c), HostValue::F32(l)) => Ok((c, l)),
                _ => anyhow::bail!("decode shard: non-f32 outputs"),
            }
        })?;
        anyhow::ensure!(results.len() == n, "decode shard: missing results");
        let next = argmax_rows(&results[0].1.data, serve_batch, v);
        let sh = self.shards.as_mut().expect("sharded path");
        for (k, (c, _)) in results.drain(..).enumerate() {
            sh.caches[k] = c;
        }
        let busy = self.kv.busy_slots();
        for k in 0..n {
            for &slot in &busy {
                self.kv.scatter_decode_row_shard(
                    &self.shards.as_ref().unwrap().caches[k],
                    slot,
                    k,
                    n,
                )?;
            }
        }
        Ok(next)
    }

    /// Host view of the contiguous cache (tests / debugging): fetches
    /// from device when resident there; gathered from the pool in the
    /// native paged mode (where no contiguous cache exists).
    pub fn cache_host(&self) -> crate::Result<Tensor> {
        if let Some(sh) = &self.shards {
            // stitch the per-shard caches [L, 2, B, Hkv/n, CAP, dh]
            // back into the full contiguous view along the head axis
            let m = &self.session.manifest;
            let (nl, b, hkv, cap, dh) = (
                m.n_layers, m.serve_batch, m.n_kv_heads, m.cache_cap, m.d_head,
            );
            let n = sh.group.n_shards();
            let loc = hkv / n;
            let row = cap * dh;
            let mut full = vec![0.0f32; nl * 2 * b * hkv * row];
            for (k, c) in sh.caches.iter().enumerate() {
                anyhow::ensure!(
                    c.data.len() == nl * 2 * b * loc * row,
                    "shard {k} cache has unexpected size"
                );
                for lw in 0..nl * 2 {
                    for bi in 0..b {
                        for h in 0..loc {
                            let src = ((lw * b + bi) * loc + h) * row;
                            let dst = ((lw * b + bi) * hkv + k * loc + h) * row;
                            full[dst..dst + row]
                                .copy_from_slice(&c.data[src..src + row]);
                        }
                    }
                }
            }
            return Ok(Tensor::new(vec![nl, 2, b, hkv, cap, dh], full));
        }
        if self.paged_attention {
            return Ok(self.kv.gather_view());
        }
        match &self.cache {
            Value::Host(HostValue::F32(t)) => Ok(t.clone()),
            Value::Host(_) => anyhow::bail!("cache is not an f32 value"),
            Value::Device(b) => literalx::fetch_f32(b),
        }
    }
}
