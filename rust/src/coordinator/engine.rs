//! The serving engine: prefill/decode execution against one variant.
//!
//! One engine = one quantization scheme (the router owns several). The KV
//! cache is threaded functionally through each graph call — the graph
//! returns the updated cache as output 0 — and is held as a *device*
//! value between steps. Three mechanisms keep the steady-state decode
//! step's host traffic at a few kilobytes (see README "Serving hot
//! path" and benches/perf_hotpath.rs for the budget):
//!
//! 1. **Resident invariants** (PR 1): weights, ranges, inv_smooth, the
//!    cushion prefix KV, and the scheme's level scalars live in the
//!    session's ResidentPool, uploaded once per configuration.
//! 2. **Device-side token selection**: when the variant ships
//!    `decode_sampled_*` / `prefill_sampled_*` graphs, greedy argmax
//!    runs in-graph and only `[B]` i32 token ids are fetched per step
//!    instead of `[B, vocab]` f32 logits (`Outputs::host_i32`).
//! 3. **Donated cache residency**: a `runtime::split::TupleSplitter`
//!    per output signature decomposes the result tuple into per-output
//!    *device* buffers, so the cache element never materializes as a
//!    host literal between steps (xla_extension 0.5.1 returns root
//!    tuples as one buffer and exposes no aliasing config; the splitter
//!    replaces the seed's fetch+re-upload with a device-side copy).
//!
//! Prefill is additionally **bucketed**: the engine picks the smallest
//! `prefill_sampled_*_b<n>` bucket >= the prompt length (manifest
//! `prefill_buckets`), so short prompts neither pad to `seq_len` nor pay
//! a full-width forward.
//!
//! All three are independently degradable: missing sampled artifacts
//! fall back to the logits graphs + host argmax, a failed splitter falls
//! back to host materialization, and `set_host_roundtrip(true)` restores
//! the seed's full per-step host round-trip for parity tests
//! (`cache_host()` fetches the cache for inspection in any mode).

use std::rc::Rc;

use crate::data::PAD;
use crate::eval::perplexity::{argmax, argmax_rows};
use crate::model::session::Session;
use crate::quant::scheme::Scheme;
use crate::runtime::literalx::{self, HostValue, IntTensor, OutValue, Value};
use crate::runtime::split::{OutSpec, TupleSplitter};
use crate::runtime::DeviceBuf;
use crate::util::tensor::Tensor;

use super::kvcache::KvManager;

pub struct Engine {
    pub session: Session,
    pub scheme: Scheme,
    pub kv: KvManager,
    /// The physical KV cache [L, 2, B, Hkv, CAP, dh]: host only at init /
    /// after reset, a device value across prefill/decode steps.
    cache: Value,
    /// Parity/debug knob: when set, the cache makes the seed's full
    /// host round-trip (fetch to f32, re-upload next step) per step and
    /// tuple splitting is bypassed.
    host_roundtrip: bool,
    /// Use the `*_sampled_*` graphs (in-graph argmax) when present.
    device_sampling: bool,
    /// Use bucketed prefill graphs when present (off = full seq_len).
    prefill_bucketing: bool,
    /// Engine-invariant scalar operands, uploaded once per engine. The
    /// cushion-length scalar lives in the session's pool (keyed with the
    /// prefix KV) so the (KV, len) pair is always coherent.
    act_levels_buf: Rc<DeviceBuf>,
    kv_levels_buf: Rc<DeviceBuf>,
    suffix: String,
    prefill_graph: String,
    decode_graph: String,
    /// Present iff the artifact exists on disk.
    decode_sampled_graph: Option<String>,
    /// Ascending bucket lengths whose `prefill_sampled_*_b<n>` artifact
    /// exists (empty = no sampled prefill available).
    sampled_buckets: Vec<usize>,
    /// On-device tuple splitters, one per output signature the engine
    /// executes. `None` = that signature degrades to host
    /// materialization (logged once at construction).
    split_decode: Option<TupleSplitter>,
    split_prefill: Option<TupleSplitter>,
    split_decode_sampled: Option<TupleSplitter>,
    split_prefill_sampled: Option<TupleSplitter>,
}

impl Engine {
    pub fn new(session: Session, scheme: Scheme) -> crate::Result<Self> {
        let m = &session.manifest;
        let cushion_len = session.cushion().map(|c| c.len).unwrap_or(0);
        let kv = KvManager::new(m.serve_batch, m.m_max, m.cache_cap, cushion_len);
        let cache = kv.initial_cache(
            m.n_layers,
            m.n_kv_heads,
            m.d_head,
            session.cushion().map(|c| &c.kv),
        );
        let client = session.registry.client();
        let act_levels_buf = Rc::new(client.upload(&Tensor::scalar(scheme.act_levels()))?);
        let kv_levels_buf = Rc::new(client.upload(&Tensor::scalar(scheme.kv_levels()))?);
        let suffix = scheme.gran.graph_suffix().to_string();

        // optional-variant probes go through has_upgrade so a partially
        // regenerated artifact dir stays on the compiled base graphs
        // (registry.rs docs) — on the reference backend everything
        // resolves to the interpreter and the sampled paths light up
        let decode_sampled = format!("decode_sampled_{suffix}");
        let decode_sampled_graph = session
            .registry
            .has_upgrade(&decode_sampled, &format!("decode_{suffix}"))
            .then_some(decode_sampled);
        let prefill_base = format!("prefill_{suffix}");
        let sampled_buckets: Vec<usize> = m
            .prefill_buckets
            .iter()
            .copied()
            .filter(|b| {
                session.registry.has_upgrade(
                    &format!("prefill_sampled_{suffix}_b{b}"),
                    &prefill_base,
                )
            })
            .collect();

        // splitters keyed by output signature (shared across buckets)
        let cache_dims = [
            m.n_layers, 2, m.serve_batch, m.n_kv_heads, m.cache_cap, m.d_head,
        ];
        let b = m.serve_batch;
        let v = m.vocab;
        // splitters exist to keep PJRT root tuples on device; the
        // reference interpreter's outputs are already per-element, so on
        // that backend none are built (and none warned about)
        let splitters_apply = client.compiles_artifacts();
        let mk = |spec: &[OutSpec], what: &str| -> Option<TupleSplitter> {
            if !splitters_apply {
                return None;
            }
            match TupleSplitter::new(client, spec) {
                Ok(s) => Some(s),
                Err(e) => {
                    log::warn!(
                        "tuple splitter for {what} unavailable ({e:#}); \
                         that path will materialize outputs on host"
                    );
                    None
                }
            }
        };
        let split_decode = mk(
            &[OutSpec::f32(&cache_dims), OutSpec::f32(&[b, v])],
            "decode",
        );
        let split_prefill = mk(
            &[OutSpec::f32(&cache_dims), OutSpec::f32(&[v])],
            "prefill",
        );
        let split_decode_sampled = decode_sampled_graph.is_some().then(|| {
            mk(
                &[
                    OutSpec::f32(&cache_dims),
                    OutSpec::i32(&[b]),
                    OutSpec::f32(&[b]),
                ],
                "decode_sampled",
            )
        }).flatten();
        let split_prefill_sampled = (!sampled_buckets.is_empty()).then(|| {
            mk(
                &[
                    OutSpec::f32(&cache_dims),
                    OutSpec::i32(&[]),
                    OutSpec::f32(&[]),
                ],
                "prefill_sampled",
            )
        }).flatten();

        Ok(Self {
            prefill_graph: format!("prefill_{suffix}"),
            decode_graph: format!("decode_{suffix}"),
            device_sampling: decode_sampled_graph.is_some()
                || !sampled_buckets.is_empty(),
            decode_sampled_graph,
            sampled_buckets,
            split_decode,
            split_prefill,
            split_decode_sampled,
            split_prefill_sampled,
            suffix,
            kv,
            cache: Value::Host(HostValue::F32(cache)),
            host_roundtrip: false,
            prefill_bucketing: true,
            act_levels_buf,
            kv_levels_buf,
            scheme,
            session,
        })
    }

    /// Rebuild the cache with the session's (possibly new) cushion.
    pub fn reset_cache(&mut self) {
        let m = &self.session.manifest;
        let cushion_len = self.session.cushion().map(|c| c.len).unwrap_or(0);
        self.kv = KvManager::new(m.serve_batch, m.m_max, m.cache_cap, cushion_len);
        let cache = self.kv.initial_cache(
            m.n_layers,
            m.n_kv_heads,
            m.d_head,
            self.session.cushion().map(|c| &c.kv),
        );
        self.cache = Value::Host(HostValue::F32(cache));
    }

    /// Force the seed's per-step host round-trip of the cache (decode
    /// parity tests); the device-resident path is the default.
    pub fn set_host_roundtrip(&mut self, on: bool) {
        self.host_roundtrip = on;
    }

    /// Toggle in-graph token selection (effective only when the variant
    /// ships `*_sampled_*` artifacts). Off = fetch logits, argmax on
    /// host — the parity reference for the sampling tests.
    pub fn set_device_sampling(&mut self, on: bool) {
        self.device_sampling = on;
    }

    /// Whether the sampled decode graph is actually available.
    pub fn sampled_decode_available(&self) -> bool {
        self.decode_sampled_graph.is_some()
    }

    /// Bucket lengths with a sampled prefill artifact (ascending).
    pub fn sampled_prefill_buckets(&self) -> &[usize] {
        &self.sampled_buckets
    }

    /// Toggle bucketed prefill (off = always the full seq_len bucket;
    /// the bucket-boundary parity tests compare the two).
    pub fn set_prefill_bucketing(&mut self, on: bool) {
        self.prefill_bucketing = on;
    }

    pub fn cushion_len(&self) -> usize {
        self.session.cushion().map(|c| c.len).unwrap_or(0)
    }

    /// The cache operand for the next step. A `Device` cache is a cheap
    /// `Rc` clone; the `Host` form only exists right after init/reset
    /// (one copy). Cloning (rather than moving the cache out) keeps the
    /// engine retryable: a failed step leaves `self.cache` untouched.
    fn cache_arg(&self) -> Value {
        self.cache.clone()
    }

    /// Store the cache output of a step per the residency mode.
    fn store_cache(&mut self, out: OutValue) -> crate::Result<()> {
        self.cache = if self.host_roundtrip {
            Value::Host(HostValue::F32(out.to_tensor()?))
        } else {
            out.into_value(self.session.registry.client())?
        };
        Ok(())
    }

    /// The plan for one prefill: (graph name, padded length, sampled?).
    /// Sampled + bucketed when artifacts allow, else the legacy
    /// full-length logits graph.
    fn prefill_plan(&self, prompt_len: usize) -> (String, usize, bool) {
        let seq_len = self.session.manifest.seq_len;
        if self.device_sampling {
            let candidates: &[usize] = if self.prefill_bucketing {
                &self.sampled_buckets
            } else {
                // full-length only: the last bucket is seq_len by
                // construction when bucketed artifacts exist
                match self.sampled_buckets.last() {
                    Some(last) if *last == seq_len => {
                        std::slice::from_ref(self.sampled_buckets.last().unwrap())
                    }
                    _ => &[],
                }
            };
            if let Some(&b) = candidates.iter().find(|&&b| b >= prompt_len) {
                return (
                    format!("prefill_sampled_{}_b{b}", self.suffix),
                    b,
                    true,
                );
            }
        }
        (self.prefill_graph.clone(), seq_len, false)
    }

    /// Prefill `tokens` into `slot`; returns the first generated token.
    pub fn prefill(&mut self, slot: usize, tokens: &[i32]) -> crate::Result<i32> {
        let m = &self.session.manifest;
        anyhow::ensure!(tokens.len() <= m.seq_len, "prompt too long");
        let (graph, bucket, sampled) = self.prefill_plan(tokens.len());
        let mut padded = tokens.to_vec();
        padded.resize(bucket, PAD);
        let splitter = if self.host_roundtrip {
            None
        } else if sampled {
            self.split_prefill_sampled.as_ref()
        } else {
            self.split_prefill.as_ref()
        };
        let mut outs = self.session.run_values_split(
            &graph,
            vec![
                self.cache_arg(),
                self.session.prefix_kv_value()?,
                self.session.prefix_len_value()?,
                Value::scalar_i32(slot as i32),
                Value::Host(HostValue::I32(IntTensor::vec(padded))),
                Value::scalar_i32(tokens.len() as i32),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
            splitter,
        )?;
        if sampled {
            anyhow::ensure!(outs.len() == 3, "prefill_sampled: expected 3 outputs");
            let ids = outs.host_i32(1)?;
            anyhow::ensure!(ids.data.len() == 1, "prefill_sampled: want 1 id");
            self.store_cache(outs.take(0)?)?;
            Ok(ids.data[0])
        } else {
            anyhow::ensure!(outs.len() == 2, "prefill: expected 2 outputs");
            let logits = outs.host_f32(1)?;
            self.store_cache(outs.take(0)?)?;
            Ok(argmax(&logits.data) as i32)
        }
    }

    /// One decode step for all slots; `tokens[b]` is the last generated
    /// token of slot b (PAD for inactive slots). Returns next tokens [B].
    pub fn decode_step(&mut self, tokens: &[i32]) -> crate::Result<Vec<i32>> {
        let (serve_batch, v) =
            (self.session.manifest.serve_batch, self.session.manifest.vocab);
        anyhow::ensure!(tokens.len() == serve_batch);
        let sampled = self.device_sampling && self.decode_sampled_graph.is_some();
        let graph = match (&self.decode_sampled_graph, sampled) {
            (Some(g), true) => g.clone(),
            _ => self.decode_graph.clone(),
        };
        let splitter = if self.host_roundtrip {
            None
        } else if sampled {
            self.split_decode_sampled.as_ref()
        } else {
            self.split_decode.as_ref()
        };
        let mut outs = self.session.run_values_split(
            &graph,
            vec![
                self.cache_arg(),
                Value::Host(HostValue::I32(IntTensor::vec(self.kv.lens_i32()))),
                self.session.prefix_len_value()?,
                Value::Host(HostValue::I32(IntTensor::vec(tokens.to_vec()))),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
            splitter,
        )?;
        if sampled {
            anyhow::ensure!(outs.len() == 3, "decode_sampled: expected 3 outputs");
            let ids = outs.host_i32(1)?;
            anyhow::ensure!(
                ids.data.len() == serve_batch,
                "decode_sampled: want [B] ids"
            );
            self.store_cache(outs.take(0)?)?;
            Ok(ids.data)
        } else {
            anyhow::ensure!(outs.len() == 2, "decode: expected 2 outputs");
            let logits = outs.host_f32(1)?;
            self.store_cache(outs.take(0)?)?;
            Ok(argmax_rows(&logits.data, serve_batch, v))
        }
    }

    /// Host view of the cache (tests / debugging): fetches from device
    /// when the cache is resident there.
    pub fn cache_host(&self) -> crate::Result<Tensor> {
        match &self.cache {
            Value::Host(HostValue::F32(t)) => Ok(t.clone()),
            Value::Host(_) => anyhow::bail!("cache is not an f32 value"),
            Value::Device(b) => literalx::fetch_f32(b),
        }
    }
}
