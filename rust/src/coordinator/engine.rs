//! The serving engine: prefill/decode execution against one variant.
//!
//! One engine = one quantization scheme (the router owns several). The KV
//! cache is threaded functionally through each graph call: the graph
//! returns the updated cache as output 0, which replaces the engine's
//! copy. xla_extension 0.5.1's PJRT wrapper returns multi-output programs
//! as one tuple literal, so the cache makes a host round-trip per step
//! (~10 MB memcpy, measured in EXPERIMENTS.md §Perf); weights stay
//! device-resident.

use crate::data::PAD;
use crate::model::session::Session;
use crate::quant::scheme::Scheme;
use crate::runtime::literalx::{HostValue, IntTensor};
use crate::util::tensor::Tensor;

use super::kvcache::KvManager;

pub struct Engine {
    pub session: Session,
    pub scheme: Scheme,
    pub kv: KvManager,
    cache: Tensor,
    prefill_graph: String,
    decode_graph: String,
}

impl Engine {
    pub fn new(session: Session, scheme: Scheme) -> crate::Result<Self> {
        let m = &session.manifest;
        let cushion_len = session.cushion.as_ref().map(|c| c.len).unwrap_or(0);
        let kv = KvManager::new(m.serve_batch, m.m_max, m.cache_cap, cushion_len);
        let cache = kv.initial_cache(
            m.n_layers,
            m.n_kv_heads,
            m.d_head,
            session.cushion.as_ref().map(|c| &c.kv),
        );
        let suffix = scheme.gran.graph_suffix();
        Ok(Self {
            prefill_graph: format!("prefill_{suffix}"),
            decode_graph: format!("decode_{suffix}"),
            kv,
            scheme,
            session,
            cache,
        })
    }

    /// Rebuild the cache with the session's (possibly new) cushion.
    pub fn reset_cache(&mut self) {
        let m = &self.session.manifest;
        self.kv = KvManager::new(
            m.serve_batch, m.m_max, m.cache_cap, self.cushion_len());
        self.cache = self.kv.initial_cache(
            m.n_layers,
            m.n_kv_heads,
            m.d_head,
            self.session.cushion.as_ref().map(|c| &c.kv),
        );
    }

    pub fn cushion_len(&self) -> usize {
        self.session.cushion.as_ref().map(|c| c.len).unwrap_or(0)
    }

    /// Prefill `tokens` into `slot`; returns the first generated token.
    pub fn prefill(&mut self, slot: usize, tokens: &[i32]) -> crate::Result<i32> {
        let m = &self.session.manifest;
        anyhow::ensure!(tokens.len() <= m.seq_len, "prompt too long");
        let mut padded = tokens.to_vec();
        padded.resize(m.seq_len, PAD);
        let (pkv, _plen) = self.session.prefix_args();
        let cache = std::mem::replace(&mut self.cache, Tensor::zeros(&[0]));
        let outs = self.session.run(
            &self.prefill_graph,
            &[
                HostValue::F32(cache),
                HostValue::F32(pkv),
                HostValue::scalar_i32(self.cushion_len() as i32),
                HostValue::scalar_i32(slot as i32),
                HostValue::I32(IntTensor::vec(padded)),
                HostValue::scalar_i32(tokens.len() as i32),
                HostValue::F32(self.session.ranges.clone()),
                HostValue::scalar_f32(self.scheme.act_levels()),
                HostValue::scalar_f32(self.scheme.kv_levels()),
                HostValue::F32(self.session.inv_smooth.clone()),
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "prefill: expected 2 outputs");
        let mut it = outs.into_iter();
        self.cache = it.next().unwrap();
        let logits = it.next().unwrap();
        Ok(crate::eval::perplexity::argmax(&logits.data) as i32)
    }

    /// One decode step for all slots; `tokens[b]` is the last generated
    /// token of slot b (PAD for inactive slots). Returns next tokens [B].
    pub fn decode_step(&mut self, tokens: &[i32]) -> crate::Result<Vec<i32>> {
        let m = &self.session.manifest;
        anyhow::ensure!(tokens.len() == m.serve_batch);
        let cache = std::mem::replace(&mut self.cache, Tensor::zeros(&[0]));
        let outs = self.session.run(
            &self.decode_graph,
            &[
                HostValue::F32(cache),
                HostValue::I32(IntTensor::vec(self.kv.lens_i32())),
                HostValue::scalar_i32(self.cushion_len() as i32),
                HostValue::I32(IntTensor::vec(tokens.to_vec())),
                HostValue::F32(self.session.ranges.clone()),
                HostValue::scalar_f32(self.scheme.act_levels()),
                HostValue::scalar_f32(self.scheme.kv_levels()),
                HostValue::F32(self.session.inv_smooth.clone()),
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "decode: expected 2 outputs");
        let mut it = outs.into_iter();
        self.cache = it.next().unwrap();
        let logits = it.next().unwrap();
        let v = m.vocab;
        Ok((0..m.serve_batch)
            .map(|b| {
                crate::eval::perplexity::argmax(&logits.data[b * v..(b + 1) * v])
                    as i32
            })
            .collect())
    }

    /// Host view of the cache (tests / debugging).
    pub fn cache_host(&self) -> &Tensor {
        &self.cache
    }
}
