//! The serving engine: prefill/decode execution against one variant.
//!
//! One engine = one quantization scheme (the router owns several). The KV
//! cache is threaded functionally through each graph call — the graph
//! returns the updated cache as output 0 — and is held as a *device*
//! value between steps: loop-invariant operands (weights, ranges,
//! inv_smooth, the cushion prefix KV, the scheme's level scalars) are
//! device-resident via the session's ResidentPool, and only the logits
//! are materialized to host f32 per step (for argmax). xla_extension
//! 0.5.1 still returns multi-output programs as one tuple literal, so the
//! cache element crosses the boundary once per step as a raw literal —
//! but without the seed's f32 `to_vec` conversion, `Tensor` re-alloc, or
//! the per-step re-upload of every constant operand (that was ~10 MB of
//! avoidable memcpy per step; see benches/perf_hotpath.rs for the
//! before/after breakdown and BENCH_perf_hotpath.json for the trail).
//! `set_host_roundtrip(true)` restores the seed's host round-trip
//! semantics for parity tests; `cache_host()` fetches the cache for
//! inspection.

use std::rc::Rc;

use crate::data::PAD;
use crate::model::session::Session;
use crate::quant::scheme::Scheme;
use crate::runtime::literalx::{self, HostValue, IntTensor, OutValue, Value};
use crate::util::tensor::Tensor;

use super::kvcache::KvManager;

pub struct Engine {
    pub session: Session,
    pub scheme: Scheme,
    pub kv: KvManager,
    /// The physical KV cache [L, 2, B, Hkv, CAP, dh]: host only at init /
    /// after reset, a device value across prefill/decode steps.
    cache: Value,
    /// Parity/debug knob: when set, the cache makes the seed's full
    /// host round-trip (fetch to f32, re-upload next step) per step.
    host_roundtrip: bool,
    /// Engine-invariant scalar operands, uploaded once per engine. The
    /// cushion-length scalar lives in the session's pool (keyed with the
    /// prefix KV) so the (KV, len) pair is always coherent.
    act_levels_buf: Rc<xla::PjRtBuffer>,
    kv_levels_buf: Rc<xla::PjRtBuffer>,
    prefill_graph: String,
    decode_graph: String,
}

impl Engine {
    pub fn new(session: Session, scheme: Scheme) -> crate::Result<Self> {
        let m = &session.manifest;
        let cushion_len = session.cushion().map(|c| c.len).unwrap_or(0);
        let kv = KvManager::new(m.serve_batch, m.m_max, m.cache_cap, cushion_len);
        let cache = kv.initial_cache(
            m.n_layers,
            m.n_kv_heads,
            m.d_head,
            session.cushion().map(|c| &c.kv),
        );
        let client = session.registry.client();
        let act_levels_buf = Rc::new(client.upload(&Tensor::scalar(scheme.act_levels()))?);
        let kv_levels_buf = Rc::new(client.upload(&Tensor::scalar(scheme.kv_levels()))?);
        let suffix = scheme.gran.graph_suffix();
        Ok(Self {
            prefill_graph: format!("prefill_{suffix}"),
            decode_graph: format!("decode_{suffix}"),
            kv,
            cache: Value::Host(HostValue::F32(cache)),
            host_roundtrip: false,
            act_levels_buf,
            kv_levels_buf,
            scheme,
            session,
        })
    }

    /// Rebuild the cache with the session's (possibly new) cushion.
    pub fn reset_cache(&mut self) {
        let m = &self.session.manifest;
        let cushion_len = self.session.cushion().map(|c| c.len).unwrap_or(0);
        self.kv = KvManager::new(m.serve_batch, m.m_max, m.cache_cap, cushion_len);
        let cache = self.kv.initial_cache(
            m.n_layers,
            m.n_kv_heads,
            m.d_head,
            self.session.cushion().map(|c| &c.kv),
        );
        self.cache = Value::Host(HostValue::F32(cache));
    }

    /// Force the seed's per-step host round-trip of the cache (decode
    /// parity tests); the device-resident path is the default.
    pub fn set_host_roundtrip(&mut self, on: bool) {
        self.host_roundtrip = on;
    }

    pub fn cushion_len(&self) -> usize {
        self.session.cushion().map(|c| c.len).unwrap_or(0)
    }

    /// The cache operand for the next step. A `Device` cache is a cheap
    /// `Rc` clone; the `Host` form only exists right after init/reset
    /// (one copy). Cloning (rather than moving the cache out) keeps the
    /// engine retryable: a failed step leaves `self.cache` untouched.
    fn cache_arg(&self) -> Value {
        self.cache.clone()
    }

    /// Store the cache output of a step per the residency mode.
    fn store_cache(&mut self, out: OutValue) -> crate::Result<()> {
        self.cache = if self.host_roundtrip {
            Value::Host(HostValue::F32(out.to_tensor()?))
        } else {
            out.into_value(self.session.registry.client())?
        };
        Ok(())
    }

    /// Prefill `tokens` into `slot`; returns the first generated token.
    pub fn prefill(&mut self, slot: usize, tokens: &[i32]) -> crate::Result<i32> {
        let m = &self.session.manifest;
        anyhow::ensure!(tokens.len() <= m.seq_len, "prompt too long");
        let mut padded = tokens.to_vec();
        padded.resize(m.seq_len, PAD);
        let mut outs = self.session.run_values(
            &self.prefill_graph,
            vec![
                self.cache_arg(),
                self.session.prefix_kv_value()?,
                self.session.prefix_len_value()?,
                Value::scalar_i32(slot as i32),
                Value::Host(HostValue::I32(IntTensor::vec(padded))),
                Value::scalar_i32(tokens.len() as i32),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "prefill: expected 2 outputs");
        let logits = outs.host_f32(1)?;
        self.store_cache(outs.take(0)?)?;
        Ok(crate::eval::perplexity::argmax(&logits.data) as i32)
    }

    /// One decode step for all slots; `tokens[b]` is the last generated
    /// token of slot b (PAD for inactive slots). Returns next tokens [B].
    pub fn decode_step(&mut self, tokens: &[i32]) -> crate::Result<Vec<i32>> {
        let (serve_batch, v) =
            (self.session.manifest.serve_batch, self.session.manifest.vocab);
        anyhow::ensure!(tokens.len() == serve_batch);
        let mut outs = self.session.run_values(
            &self.decode_graph,
            vec![
                self.cache_arg(),
                Value::Host(HostValue::I32(IntTensor::vec(self.kv.lens_i32()))),
                self.session.prefix_len_value()?,
                Value::Host(HostValue::I32(IntTensor::vec(tokens.to_vec()))),
                self.session.ranges_value()?,
                Value::Device(self.act_levels_buf.clone()),
                Value::Device(self.kv_levels_buf.clone()),
                self.session.inv_smooth_value()?,
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "decode: expected 2 outputs");
        let logits = outs.host_f32(1)?;
        self.store_cache(outs.take(0)?)?;
        Ok((0..serve_batch)
            .map(|b| {
                crate::eval::perplexity::argmax(&logits.data[b * v..(b + 1) * v])
                    as i32
            })
            .collect())
    }

    /// Host view of the cache (tests / debugging): fetches from device
    /// when the cache is resident there.
    pub fn cache_host(&self) -> crate::Result<Tensor> {
        match &self.cache {
            Value::Host(HostValue::F32(t)) => Ok(t.clone()),
            Value::Host(_) => anyhow::bail!("cache is not an f32 value"),
            Value::Device(b) => literalx::fetch_f32(b),
        }
    }
}
