//! KV-cache slot manager.
//!
//! The physical cache is one device tensor [L, 2, B, Hkv, CAP, dh]
//! (layout shared with python/compile/serving.py): slot positions
//! [0, M_MAX) hold the CushionCache prefix, positions [M_MAX, CAP) the
//! request tokens. This module owns the *logical* side: slot allocation,
//! per-slot token counts, and the host-built initial cache tensor with
//! the cushion written into every slot.

use crate::util::tensor::Tensor;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SlotState {
    Free,
    /// Occupied by a request (id), holding `tokens` cache entries.
    Busy { request: u64 },
}

#[derive(Clone, Debug)]
pub struct KvManager {
    pub n_slots: usize,
    pub m_max: usize,
    pub cap: usize,
    pub cushion_len: usize,
    states: Vec<SlotState>,
    tok_len: Vec<usize>,
}

impl KvManager {
    pub fn new(n_slots: usize, m_max: usize, cap: usize, cushion_len: usize) -> Self {
        assert!(cushion_len <= m_max);
        Self {
            n_slots,
            m_max,
            cap,
            cushion_len,
            states: vec![SlotState::Free; n_slots],
            tok_len: vec![0; n_slots],
        }
    }

    /// Build the initial host cache with the cushion KV broadcast into
    /// every slot's prefix region. cushion_kv: [L, 2, Hkv, M_MAX, dh].
    pub fn initial_cache(&self, n_layers: usize, n_kv_heads: usize,
                         d_head: usize, cushion_kv: Option<&Tensor>) -> Tensor {
        let mut cache = Tensor::zeros(&[
            n_layers, 2, self.n_slots, n_kv_heads, self.cap, d_head,
        ]);
        if let Some(kv) = cushion_kv {
            assert_eq!(kv.shape, vec![n_layers, 2, n_kv_heads, self.m_max, d_head]);
            // The m_max d_head-rows of one (l, w, h) source block are
            // contiguous in both layouts (dest positions [0, m_max) sit at
            // the head of the cap-row), so each lands as one slice copy.
            let src_block = self.m_max * d_head;
            let dst_row = self.cap * d_head;
            for l in 0..n_layers {
                for w in 0..2 {
                    for h in 0..n_kv_heads {
                        let s0 = ((l * 2 + w) * n_kv_heads + h) * src_block;
                        let src = &kv.data[s0..s0 + src_block];
                        for b in 0..self.n_slots {
                            let d0 = (((l * 2 + w) * self.n_slots + b)
                                * n_kv_heads + h) * dst_row;
                            cache.data[d0..d0 + src_block].copy_from_slice(src);
                        }
                    }
                }
            }
        }
        cache
    }

    pub fn alloc(&mut self, request: u64, prompt_len: usize) -> Option<usize> {
        if self.m_max + prompt_len > self.cap {
            return None; // cannot ever fit
        }
        let slot = self.states.iter().position(|s| *s == SlotState::Free)?;
        self.states[slot] = SlotState::Busy { request };
        self.tok_len[slot] = prompt_len;
        Some(slot)
    }

    pub fn free(&mut self, slot: usize) {
        self.states[slot] = SlotState::Free;
        self.tok_len[slot] = 0;
    }

    pub fn request_of(&self, slot: usize) -> Option<u64> {
        match self.states[slot] {
            SlotState::Busy { request } => Some(request),
            SlotState::Free => None,
        }
    }

    pub fn tok_len(&self, slot: usize) -> usize {
        self.tok_len[slot]
    }

    /// Record one decoded token appended to `slot`.
    pub fn push_token(&mut self, slot: usize) {
        self.tok_len[slot] += 1;
        debug_assert!(self.m_max + self.tok_len[slot] <= self.cap);
    }

    /// Room left (in tokens) for this slot.
    pub fn remaining(&self, slot: usize) -> usize {
        self.cap - self.m_max - self.tok_len[slot]
    }

    pub fn busy_slots(&self) -> Vec<usize> {
        (0..self.n_slots)
            .filter(|&s| matches!(self.states[s], SlotState::Busy { .. }))
            .collect()
    }

    pub fn free_count(&self) -> usize {
        self.states.iter().filter(|s| **s == SlotState::Free).count()
    }

    /// Per-slot token lengths for the decode graph's cache_tok_len input.
    pub fn lens_i32(&self) -> Vec<i32> {
        self.tok_len.iter().map(|&l| l as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut kv = KvManager::new(2, 4, 20, 2);
        let a = kv.alloc(10, 5).unwrap();
        let b = kv.alloc(11, 5).unwrap();
        assert_ne!(a, b);
        assert!(kv.alloc(12, 5).is_none(), "no free slot");
        kv.free(a);
        assert_eq!(kv.free_count(), 1);
        let c = kv.alloc(12, 5).unwrap();
        assert_eq!(c, a);
        assert_eq!(kv.request_of(c), Some(12));
    }

    #[test]
    fn capacity_respected() {
        let mut kv = KvManager::new(1, 4, 10, 0);
        assert!(kv.alloc(1, 7).is_none(), "prompt longer than cap");
        let s = kv.alloc(1, 4).unwrap();
        assert_eq!(kv.remaining(s), 2);
        kv.push_token(s);
        assert_eq!(kv.remaining(s), 1);
        assert_eq!(kv.lens_i32(), vec![5]);
    }

    #[test]
    fn initial_cache_embeds_cushion() {
        let kv = KvManager::new(2, 2, 4, 1);
        let mut cushion = Tensor::zeros(&[1, 2, 1, 2, 3]);
        cushion.data[0] = 7.0; // l0, k, h0, p0, d0
        let cache = kv.initial_cache(1, 1, 3, Some(&cushion));
        assert_eq!(cache.shape, vec![1, 2, 2, 1, 4, 3]);
        // both slots' position 0 carry the value
        for b in 0..2 {
            let idx = ((((0 * 2 + 0) * 2 + b) * 1 + 0) * 4 + 0) * 3 + 0;
            assert_eq!(cache.data[idx], 7.0, "slot {b}");
        }
    }
}
