//! Host-side f32 tensor: the marshalling currency between the weight
//! bundle, the quantization transforms (SmoothQuant / AWQ / QuaRot /
//! weight qdq run host-side on these), and the PJRT literals.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs {} elems", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self::new(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self::new(shape.to_vec(), vec![v; shape.iter().product()])
    }

    pub fn scalar(v: f32) -> Self {
        Self::new(vec![], vec![v])
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let (_, c) = self.dims2();
        self.data[i * c + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = self.dims2();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (_, c) = self.dims2();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Per-column absolute maximum of a rank-2 tensor.
    pub fn col_absmax(&self) -> Vec<f32> {
        let (_, c) = self.dims2();
        let mut out = vec![0.0f32; c];
        if c == 0 {
            return out;
        }
        for row in self.data.chunks_exact(c) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o = o.max(v.abs());
            }
        }
        out
    }

    /// Per-row absolute maximum of a rank-2 tensor.
    pub fn row_absmax(&self) -> Vec<f32> {
        let (r, _) = self.dims2();
        (0..r)
            .map(|i| self.row(i).iter().fold(0.0f32, |a, &v| a.max(v.abs())))
            .collect()
    }

    /// Scale row i by s[i] (rank-2).
    pub fn scale_rows(&mut self, s: &[f32]) {
        let (r, _) = self.dims2();
        assert_eq!(s.len(), r);
        for i in 0..r {
            let f = s[i];
            for v in self.row_mut(i) {
                *v *= f;
            }
        }
    }

    /// Scale column j by s[j] (rank-2 or rank-1).
    pub fn scale_cols(&mut self, s: &[f32]) {
        if self.rank() == 1 {
            assert_eq!(s.len(), self.data.len());
            for (v, f) in self.data.iter_mut().zip(s) {
                *v *= f;
            }
            return;
        }
        let (_, c) = self.dims2();
        assert_eq!(s.len(), c);
        if c == 0 {
            return;
        }
        for row in self.data.chunks_exact_mut(c) {
            for (v, &f) in row.iter_mut().zip(s) {
                *v *= f;
            }
        }
    }

    /// `self @ other` for rank-2 tensors (used by the QuaRot rotation
    /// folding; sizes here are at most d x d_ff).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = other.dims2();
        assert_eq!(k, k2);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[p * n..(p + 1) * n];
                let dst = &mut out[i * n..(i + 1) * n];
                for (d, &o) in dst.iter_mut().zip(orow) {
                    *d += a * o;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    pub fn absmax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
    }
}

/// Orthonormal Sylvester Hadamard matrix (QuaRot rotation).
pub fn hadamard(n: usize) -> Tensor {
    assert!(n.is_power_of_two(), "hadamard size must be a power of two");
    let mut h = vec![1.0f32];
    let mut m = 1;
    while m < n {
        let mut next = vec![0.0f32; 4 * m * m];
        for i in 0..m {
            for j in 0..m {
                let v = h[i * m + j];
                next[i * 2 * m + j] = v;
                next[i * 2 * m + m + j] = v;
                next[(m + i) * 2 * m + j] = v;
                next[(m + i) * 2 * m + m + j] = -v;
            }
        }
        h = next;
        m *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    Tensor::new(vec![n, n], h.into_iter().map(|v| v * norm).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut id = Tensor::zeros(&[3, 3]);
        for i in 0..3 {
            id.set2(i, i, 1.0);
        }
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn hadamard_orthonormal() {
        for n in [2usize, 8, 64] {
            let h = hadamard(n);
            let hht = h.matmul(&h.transpose2());
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((hht.at2(i, j) - want).abs() < 1e-4, "{n} {i} {j}");
                }
            }
        }
    }

    #[test]
    fn row_col_scaling() {
        let mut a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        a.scale_rows(&[2.0, 0.5]);
        assert_eq!(a.data, vec![2., 4., 1.5, 2.]);
        a.scale_cols(&[1.0, 10.0]);
        assert_eq!(a.data, vec![2., 40., 1.5, 20.]);
    }

    #[test]
    fn absmax_helpers() {
        let a = Tensor::new(vec![2, 3], vec![1., -5., 3., -2., 4., 0.]);
        assert_eq!(a.col_absmax(), vec![2., 5., 3.]);
        assert_eq!(a.row_absmax(), vec![5., 4.]);
        assert_eq!(a.absmax(), 5.0);
    }
}
