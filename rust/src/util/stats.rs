//! Descriptive statistics for the bench harness and the metrics module
//! (substrate for the absent criterion crate).

/// Running mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    let mut w = Welford::default();
    for &x in xs {
        w.push(x);
    }
    w.std()
}

/// Percentile with linear interpolation (numpy's default method).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Percentile by the nearest-rank rule: the result is always an
/// *actual sample*, so any histogram bucketing of the same data agrees
/// with it — the bucket containing the returned value is provably
/// non-empty. The interpolated `percentile` above can land between two
/// samples, inside a bucket with count zero, which is exactly the
/// summary-vs-histogram disagreement the metrics consistency test
/// pins (coordinator::metrics).
pub fn percentile_nearest(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0 * s.len() as f64).ceil() as usize;
    s[rank.saturating_sub(1).min(s.len() - 1)]
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[3.0]), 3.0);
    }

    #[test]
    fn percentile_nearest_returns_actual_samples() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile_nearest(&xs, 0.0), 1.0);
        assert_eq!(percentile_nearest(&xs, 50.0), 2.0);
        assert_eq!(percentile_nearest(&xs, 75.0), 3.0);
        assert_eq!(percentile_nearest(&xs, 100.0), 4.0);
        assert_eq!(percentile_nearest(&[], 99.0), 0.0);
        // the defining property: the result is a member of the input
        let many: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        for p in [1.0, 50.0, 90.0, 99.0] {
            let v = percentile_nearest(&many, p);
            assert!(many.contains(&v), "p{p} -> {v} must be a sample");
        }
    }
}
