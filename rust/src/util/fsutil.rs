//! Little-endian binary readers for the artifact formats
//! (python/compile/binio.py) and artifact-directory helpers.

use std::path::{Path, PathBuf};

pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn magic(&mut self, want: &[u8; 4]) -> crate::Result<()> {
        let got = self.bytes(4)?;
        if got != want {
            anyhow::bail!("bad magic: expected {want:?}, got {got:?}");
        }
        Ok(())
    }

    pub fn bytes(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        if self.remaining() < n {
            anyhow::bail!("truncated: need {n} bytes, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> crate::Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn string(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.bytes(n)?.to_vec())?)
    }

    pub fn f32_vec(&mut self, n: usize) -> crate::Result<Vec<f32>> {
        let b = self.bytes(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn i32_vec(&mut self, n: usize) -> crate::Result<Vec<i32>> {
        let b = self.bytes(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Locate the artifacts directory: $CUSHION_ARTIFACTS or ./artifacts
/// relative to the workspace root (walking up from cwd).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CUSHION_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

pub fn variant_dir(variant: &str) -> PathBuf {
    artifacts_dir().join(variant)
}

pub fn read(path: &Path) -> crate::Result<Vec<u8>> {
    std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))
}

/// Crash-consistent write: the bytes land in `<path>.tmp` and are
/// renamed into place (rename within one directory is atomic on POSIX),
/// so a crash mid-write can never leave a torn `<path>` behind — the
/// old contents, if any, survive intact.
///
/// Under an armed fault plan with `torn=` set, this simulates exactly
/// that crash: a *truncated* temp file is written, the rename never
/// happens, and the call errors — `should_tear` proves the target file
/// is untouched.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> crate::Result<()> {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    let tmp = PathBuf::from(os);
    if crate::runtime::faults::should_tear() {
        let cut = bytes.len() / 2;
        std::fs::write(&tmp, &bytes[..cut])?;
        anyhow::bail!(
            "fault-injected(torn): simulated crash mid-write of {path:?} \
             ({cut}/{} bytes in {tmp:?}, never renamed)",
            bytes.len()
        );
    }
    std::fs::write(&tmp, bytes)
        .map_err(|e| anyhow::anyhow!("writing {tmp:?}: {e}"))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("installing {path:?}: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cursor_reads() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"CCW1");
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(b"hi");
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        buf.extend_from_slice(&(-7i32).to_le_bytes());
        let mut c = Cursor::new(&buf);
        c.magic(b"CCW1").unwrap();
        assert_eq!(c.u32().unwrap(), 3);
        assert_eq!(c.string().unwrap(), "hi");
        assert_eq!(c.f32_vec(1).unwrap(), vec![1.5]);
        assert_eq!(c.i32_vec(1).unwrap(), vec![-7]);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn cursor_truncation_errors() {
        let buf = [1u8, 2];
        let mut c = Cursor::new(&buf);
        assert!(c.u32().is_err());
        let mut c = Cursor::new(b"XXXX");
        assert!(c.magic(b"CCW1").is_err());
    }
}
