//! Minimal JSON: parse + serialize (substrate for the absent serde_json).
//!
//! Supports the full JSON grammar minus exotic float forms; preserves
//! object insertion order (manifests are written by python with a stable
//! key order and the tests golden-compare round trips).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> crate::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json field '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json field '{key}' is not a number"))
    }

    pub fn to_map(&self) -> BTreeMap<String, Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; a bare `NaN` on
                    // the wire is unparseable by any client
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(kvs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for building objects inline.
pub fn obj(kvs: Vec<(&str, Value)>) -> Value {
    Value::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Value>>(it: I) -> Value {
    Value::Arr(it.into_iter().collect())
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> crate::Result<Value> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> crate::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of json"))
    }

    fn eat(&mut self, c: u8) -> crate::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Value) -> crate::Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> crate::Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode multibyte utf-8 starting here
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let s = std::str::from_utf8(&self.b[start..start + width])?;
                        out.push_str(s);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    /// Four hex digits of a `\u` escape, bounds-checked: truncated
    /// input (`"\u12`) is a parse error, never a slice panic.
    fn hex4(&mut self) -> crate::Result<u32> {
        anyhow::ensure!(
            self.i + 4 <= self.b.len(),
            "truncated \\u escape at byte {}",
            self.i
        );
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape '{hex}' at byte {}", self.i))?;
        self.i += 4;
        Ok(cp)
    }

    /// A `\u` escape, positioned just past the `u`. Decodes UTF-16
    /// surrogate pairs (`\ud83d\ude00` -> one U+1F600) into the real
    /// code point; a lone surrogate becomes U+FFFD (tolerated),
    /// while truncation is an error (never a panic).
    fn unicode_escape(&mut self) -> crate::Result<char> {
        let cp = self.hex4()?;
        if (0xD800..0xDC00).contains(&cp) {
            // high surrogate: pairs with an immediately following
            // \uDC00..\uDFFF low surrogate
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u')
            {
                let save = self.i;
                self.i += 2;
                let lo = self.hex4()?;
                if (0xDC00..0xE000).contains(&lo) {
                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                    return Ok(char::from_u32(c).unwrap_or('\u{fffd}'));
                }
                // not a low surrogate: rewind and let the main loop
                // handle that escape on its own
                self.i = save;
            }
            return Ok('\u{fffd}');
        }
        // char::from_u32 is None exactly for lone low surrogates here
        Ok(char::from_u32(cp).unwrap_or('\u{fffd}'))
    }

    fn number(&mut self) -> crate::Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> crate::Result<Value> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => anyhow::bail!("expected , or ] found '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> crate::Result<Value> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => anyhow::bail!("expected , or }} found '{}'", c as char),
            }
        }
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(t).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n \"k\" : [ 1 , 2 ] }\t").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn truncated_unicode_escape_is_err_not_panic() {
        // every prefix of a \u escape at end-of-input must Err cleanly
        for t in [r#""\u"#, r#""\u1"#, r#""\u12"#, r#""\u123"#, r#""\ud83d\ud"#] {
            assert!(parse(t).is_err(), "{t:?} should be a parse error");
        }
        // in-bounds but non-hex is an error too (the closing quote is
        // swallowed by the 4-byte window)
        assert!(parse(r#""\u12g4""#).is_err());
        assert!(parse(r#""\u12"x"#).is_err());
    }

    #[test]
    fn surrogate_pairs_decode() {
        let v = parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
        // astral round trip through an actual UTF-8 literal
        let v = parse("\"\u{1F600}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
        // lone surrogates tolerate as replacement chars
        assert_eq!(parse(r#""\ud83dx""#).unwrap().as_str().unwrap(), "\u{fffd}x");
        assert_eq!(parse(r#""\ude00""#).unwrap().as_str().unwrap(), "\u{fffd}");
        // high surrogate followed by a non-surrogate escape: both decode
        assert_eq!(
            parse(r#""\ud83dA""#).unwrap().as_str().unwrap(),
            "\u{fffd}A"
        );
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_string(), "null");
        // and stay parseable inside a document
        let doc = obj(vec![("tpot_ms", num(f64::NAN)), ("n", num(2.0))]);
        let v = parse(&doc.to_string()).unwrap();
        assert_eq!(v.get("tpot_ms"), Some(&Value::Null));
        assert_eq!(v.req_usize("n").unwrap(), 2);
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn req_accessors() {
        let v = parse(r#"{"n": 4, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }
}
