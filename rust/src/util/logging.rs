//! Minimal `log`-facade backend: timestamped stderr lines, level from
//! $CUSHION_LOG (error|warn|info|debug|trace, default info).

use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

struct StderrLogger {
    start: Instant,
    max: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, meta: &Metadata) -> bool {
        meta.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5} {}] {}", record.level(), record.target(),
                  record.args());
        // Mirror warn/error lines into the trace ring so an exported
        // trace shows *where* trouble happened relative to the spans
        // around it. Truncated: snapshot bodies do not belong in args.
        if record.level() <= Level::Warn {
            let msg: String = record.args().to_string().chars().take(120).collect();
            crate::runtime::trace::instant("log", "log", None, &[
                ("level", record.level().to_string()),
                ("target", record.target().to_string()),
                ("msg", msg),
            ]);
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceCell<StderrLogger> = OnceCell::new();

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("CUSHION_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        max: level,
    });
    let _ = log::set_logger(logger);
    log::set_max_level(LevelFilter::Trace);
}
