//! SplitMix64 PRNG, bit-for-bit identical to python/compile/prng.py.
//!
//! The synthetic-corpus grammar must generate identical token streams at
//! build time (python: training corpus, calibration splits, task sets) and
//! at serve time (rust: workload generation); the golden-parity test in
//! rust/tests/ asserts the match.

/// SplitMix64 (Steele et al.): tiny, fast, trivially portable.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)` (Lemire-style high-bits reduction,
    /// mirroring the python implementation exactly).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Derive an independent child stream (mirrors python `fork`).
    pub fn fork(&mut self, stream: u64) -> SplitMix64 {
        let base = self.next_u64();
        SplitMix64::new(base ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Standard normal via Box-Muller (rust-only; used for workload
    /// inter-arrival jitter, never for cross-language streams).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Stateless SplitMix64 finalizer (mirrors python `hash64`); used for the
/// deterministic grammar successor tables.
#[inline]
pub fn hash64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for n in [1u64, 2, 3, 14, 512, 1024] {
            for _ in 0..200 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = SplitMix64::new(1);
        let mut a = r.fork(0);
        let mut r2 = SplitMix64::new(1);
        let mut b = r2.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn known_answer_matches_python() {
        // printed by python/compile/prng.py: SplitMix64(0).next_u64()
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(hash64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
