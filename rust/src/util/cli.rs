//! Tiny declarative CLI parser (substrate for the absent clap crate).
//!
//! Supports `--name value`, `--name=value`, boolean `--flag`, positional
//! arguments, defaults, and generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

#[derive(Default)]
pub struct Cli {
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>,
}

pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(about: &str) -> Self {
        Self {
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{}\n\nOptions:\n", self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_flag) {
                (_, true) => String::new(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            out.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        for (n, h) in &self.positionals {
            out.push_str(&format!("  <{n}>  {h}\n"));
        }
        out
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse_from(&self, argv: &[String]) -> crate::Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.usage()))?;
                if spec.is_flag {
                    flags.insert(name.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                                .clone()
                        }
                    };
                    values.insert(name.to_string(), v);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(&o.name) {
                anyhow::bail!("missing required --{}\n{}", o.name, self.usage());
            }
        }
        Ok(Args {
            values,
            flags,
            positionals,
        })
    }

    pub fn parse_env(&self) -> crate::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&argv)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option {name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> crate::Result<usize> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_f64(&self, name: &str) -> crate::Result<f64> {
        Ok(self.get(name).parse()?)
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cli = Cli::new("t").opt("n", "4", "count").flag("fast", "go fast");
        let a = cli.parse_from(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 4);
        assert!(!a.flag("fast"));
        let a = cli.parse_from(&argv(&["--n", "9", "--fast"])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 9);
        assert!(a.flag("fast"));
    }

    #[test]
    fn equals_form_and_positionals() {
        let cli = Cli::new("t").opt("x", "a", "").positional("cmd", "");
        let a = cli.parse_from(&argv(&["run", "--x=b"])).unwrap();
        assert_eq!(a.get("x"), "b");
        assert_eq!(a.positionals(), &["run".to_string()]);
    }

    #[test]
    fn required_missing() {
        let cli = Cli::new("t").req("model", "");
        assert!(cli.parse_from(&argv(&[])).is_err());
        assert!(cli.parse_from(&argv(&["--model", "m"])).is_ok());
    }

    #[test]
    fn unknown_option_rejected() {
        let cli = Cli::new("t");
        assert!(cli.parse_from(&argv(&["--nope", "1"])).is_err());
    }
}
