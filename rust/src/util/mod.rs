//! Self-contained substrates. The offline crate vendor only carries the
//! `xla` crate's transitive dependencies, so the usual suspects (serde,
//! clap, rand, criterion, proptest, tokio) are reimplemented here in the
//! small form this project needs (DESIGN.md §1, substitution table).

pub mod cli;
pub mod fsutil;
pub mod json;
pub mod logging;
pub mod prng;
pub mod stats;
pub mod tensor;
