//! Run the full CushionCache discovery (greedy search + QAT prefix
//! tuning) for a variant and persist it for the benches / server.
//!
//!   cargo run --release --example search_cushion [variant] [stride] [name]
//!
//! With stride 1 this is the paper's exact Algorithm 1 (full vocabulary
//! sweep per position); larger strides trade fidelity for wall-clock and
//! are what the Table 6 bench uses to extrapolate full-sweep cost.

use cushioncache::cushion::{self, SearchCfg, TuneCfg};
use cushioncache::model::session::{Cushion, Session};

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tl-llama".into());
    let stride: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let name = std::env::args().nth(3).unwrap_or_else(|| "default".into());

    let s = Session::load(&variant)?;
    println!("== search_cushion: {variant} (stride {stride}) ==");

    let search = cushion::greedy_search(
        &s,
        &SearchCfg { vocab_stride: stride, ..Default::default() },
    )?;
    println!(
        "step 1 (greedy search): prefix {:?}\n  lq trace {:?}\n  {} candidates, {:.1}s",
        search.prefix, search.lq_trace, search.candidates_scored, search.seconds
    );

    let tuned = cushion::tune::tune_prefix(&s, &search.prefix, &TuneCfg::default())?;
    println!(
        "step 2 (QAT prefix tuning): {} steps, {:.1}s, loss {:.4} -> {:.4}",
        tuned.steps, tuned.seconds,
        tuned.loss_trace.first().unwrap(), tuned.loss_trace.last().unwrap()
    );

    let c = Cushion {
        tokens: search.prefix.clone(),
        len: search.prefix.len(),
        kv: tuned.kv,
    };
    let path = cushion::save_cushion(&variant, &name, &c)?;
    println!("saved cushion '{name}' -> {}", path.display());
    Ok(())
}
