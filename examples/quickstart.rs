//! Quickstart: load a variant, show the outlier problem, fix it with a
//! CushionCache, and generate text through the serving engine.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! What it demonstrates (≈ the paper's Figure 1 story, in one minute):
//!  1. FP baseline perplexity on heldout synwiki.
//!  2. Per-tensor static W8A8 destroys a pre-norm model (Table 1 row 2).
//!  3. Installing a CushionCache (here the warm-start <bos> cushion — run
//!     `cushiond pipeline` for the full greedy search + tuning) restores
//!     near-FP quality (Table 1 row 3).
//!  4. Serve a few requests through the continuous-batching engine.

use cushioncache::coordinator::{Engine, Scheduler};
use cushioncache::data::grammar::{Grammar, STREAM_SERVE, CORPUS_SEED};
use cushioncache::data::tokenizer::Tokenizer;
use cushioncache::eval::perplexity::perplexity;
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::util::prng::SplitMix64;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tl-llama".into());
    println!("== CushionCache quickstart: {variant} ==");

    let mut session = Session::load(&variant)?;
    let fp = Scheme::fp();
    let w8a8 = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);

    // 1) FP baseline
    let ppl_fp = perplexity(&session, &fp, "heldout", 4)?;
    println!("[1] FP baseline             heldout ppl = {ppl_fp:8.2}");

    // 2) per-tensor static W8A8, no cushion
    calibrate::calibrate_into(&mut session, w8a8.act_levels(), 4)?;
    let ppl_q = perplexity(&session, &w8a8, "heldout", 4)?;
    println!("[2] W8A8 per-tensor static  heldout ppl = {ppl_q:8.2}   (outliers!)");

    // 3) with a CushionCache prefix (<bos> warm start)
    session.set_cushion_tokens(&[cushioncache::data::BOS])?;
    calibrate::calibrate_into(&mut session, w8a8.act_levels(), 4)?;
    let ppl_c = perplexity(&session, &w8a8, "heldout", 4)?;
    println!("[3] + CushionCache          heldout ppl = {ppl_c:8.2}   (recovered)");

    // 4) serve a few generation requests through the engine
    let tokenizer = Tokenizer::new(session.manifest.vocab);
    let engine = Engine::new(session, w8a8)?;
    let mut sched = Scheduler::new(engine);
    let g = Grammar::new(tokenizer.vocab);
    let mut base = SplitMix64::new(CORPUS_SEED);
    let mut rng = base.fork(STREAM_SERVE);
    for i in 0..4 {
        let mut r = rng.fork(i);
        let doc = g.document(24, &mut r);
        sched.submit(doc, 12);
    }
    let responses = sched.run_to_completion()?;
    for r in &responses {
        println!(
            "[4] req {} ttft {:5.1} ms, {} tokens: {}",
            r.id,
            r.ttft.unwrap_or(0.0) * 1e3,
            r.tokens.len(),
            tokenizer.detokenize(&r.tokens)
        );
    }
    let m = sched.metrics.summary();
    println!(
        "    served {} reqs, {:.1} tok/s, TPOT {:.1} ms",
        m.completed,
        m.tokens_per_second(),
        m.tpot_mean * 1e3
    );
    Ok(())
}
