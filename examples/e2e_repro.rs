//! End-to-end reproduction driver (EXPERIMENTS.md §E2E): the full
//! CushionCache pipeline on one variant, exercising every layer of the
//! stack — data substrate, AOT graphs via PJRT, calibration, greedy
//! search (Alg. 1), quantization-aware prefix tuning, recalibration, and
//! the quantized evaluation grid.
//!
//!   cargo run --release --example e2e_repro [variant] [vocab_stride]
//!
//! Prints a Table-1-style row block: heldout perplexity for
//! {fp, pts, ptd, ptk} x {no cushion, + CushionCache}.

use cushioncache::cushion::{self, SearchCfg, TuneCfg};
use cushioncache::eval::perplexity::perplexity;
use cushioncache::model::session::{Cushion, Session};
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tl-llama".into());
    let stride: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    println!("== e2e reproduction: {variant} (search stride {stride}) ==");
    let t0 = std::time::Instant::now();

    let mut s = Session::load(&variant)?;
    let grid = [
        ("FP16", Scheme::fp()),
        ("Per-tensor Static", Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive)),
        ("Per-tensor Dynamic", Scheme::w8a8(Granularity::PerTensorDynamic, Algorithm::Naive)),
        ("Per-token Dynamic", Scheme::w8a8(Granularity::PerTokenDynamic, Algorithm::Naive)),
    ];

    // ---- baseline (no cushion) ------------------------------------------
    let mut before = Vec::new();
    for (label, scheme) in &grid {
        if scheme.gran.needs_calibration() {
            calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
        }
        let ppl = perplexity(&s, scheme, "heldout", 8)?;
        println!("[baseline] {label:22} ppl {ppl:8.2}");
        before.push(ppl);
    }

    // ---- stage 1: greedy prefix search (paper §4.1) ---------------------
    let search = cushion::greedy_search(
        &s,
        &SearchCfg { vocab_stride: stride, max_len: 8, ..Default::default() },
    )?;
    println!(
        "[search] prefix {:?} | lq trace {:?} | {} candidates in {:.1}s",
        search.prefix, search.lq_trace, search.candidates_scored, search.seconds
    );

    // ---- stage 2: quantization-aware prefix tuning (paper §4.2) ---------
    let tuned = cushion::tune::tune_prefix(&s, &search.prefix, &TuneCfg::default())?;
    println!(
        "[tune] {} steps in {:.1}s, loss {:.4} -> {:.4}, lq {:.5} -> {:.5}",
        tuned.steps, tuned.seconds,
        tuned.loss_trace.first().unwrap(), tuned.loss_trace.last().unwrap(),
        tuned.lq_trace.first().unwrap(), tuned.lq_trace.last().unwrap()
    );
    s.set_cushion(Cushion {
        tokens: search.prefix.clone(),
        len: search.prefix.len(),
        kv: tuned.kv,
    })?;
    cushion::save_cushion(&variant, "e2e", s.cushion().unwrap())?;

    // ---- final evaluation with the cushion ------------------------------
    println!("\n{:24} {:>12} {:>14} {:>9}", "scheme", "no cushion", "+CushionCache", "delta");
    for ((label, scheme), ppl0) in grid.iter().zip(&before) {
        if scheme.gran.needs_calibration() {
            calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
        }
        let ppl1 = perplexity(&s, scheme, "heldout", 8)?;
        let delta = if *ppl0 > 0.0 { (ppl1 - ppl0) / ppl0 * 100.0 } else { 0.0 };
        println!("{label:24} {ppl0:12.2} {ppl1:14.2} {delta:+8.1}%");
    }
    println!("\ntotal e2e wall-clock: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
