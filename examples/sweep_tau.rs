//! Extension experiment: sweep the greedy-search early-stop threshold
//! tau (paper §4.1 / Limitations: "the lack of a principled mechanism to
//! determine tau"). For each tau we run the search, install the
//! un-tuned cushion, recalibrate, and report prefix length + ppl — the
//! trade-off surface the paper leaves open.
//!
//!   cargo run --release --example sweep_tau [variant] [stride]

use cushioncache::bench::Table;
use cushioncache::cushion::{self, SearchCfg};
use cushioncache::eval::perplexity::perplexity;
use cushioncache::model::session::{Cushion, Session};
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tl-llama".into());
    let stride: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);

    let mut table = Table::new(
        &format!("tau sweep — {variant} (greedy search only, W8A8 pts)"),
        &["tau", "prefix len", "final L_q", "candidates", "search (s)",
          "heldout ppl"],
    );
    let mut s = Session::load(&variant)?;
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 4)?;
    let base = perplexity(&s, &scheme, "heldout", 4)?;
    table.row(vec!["(none)".into(), "0".into(), "-".into(), "0".into(),
                   "0.0".into(), format!("{base:.2}")]);

    for tau in [0.1f32, 0.3, 0.5, 0.7, 0.9] {
        let res = cushion::greedy_search(
            &s,
            &SearchCfg { tau, vocab_stride: stride, max_len: 8,
                         ..Default::default() },
        )?;
        let kv = s.compute_prefix_kv(&res.prefix)?;
        s.set_cushion(Cushion {
            tokens: res.prefix.clone(),
            len: res.prefix.len(),
            kv,
        })?;
        calibrate::calibrate_into(&mut s, scheme.act_levels(), 4)?;
        let ppl = perplexity(&s, &scheme, "heldout", 4)?;
        table.row(vec![
            format!("{tau:.1}"),
            format!("{}", res.prefix.len()),
            format!("{:.4}", res.lq_trace.last().unwrap()),
            format!("{}", res.candidates_scored),
            format!("{:.1}", res.seconds),
            format!("{ppl:.2}"),
        ]);
        s.clear_cushion();
    }
    table.emit("sweep_tau");
    println!("(the paper's tau=0.5 sits where the length/quality curve \
              flattens — a one-token cushion already recovers this substrate)");
    Ok(())
}
