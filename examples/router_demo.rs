//! Multi-engine router demo: one FP engine and one per-tensor-static
//! engine behind the mode router, with mixed traffic routed by requested
//! quantization mode and least-loaded replica selection — the
//! vLLM-router-shaped deployment story of DESIGN.md §2.
//!
//!   cargo run --release --example router_demo [variant]

use cushioncache::coordinator::router::Router;
use cushioncache::coordinator::{Engine, Request, Scheduler};
use cushioncache::data::grammar::{Grammar, CORPUS_SEED, STREAM_SERVE};
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::util::prng::SplitMix64;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tl-llama".into());

    let mut router = Router::new();
    for (mode, scheme) in [
        ("fp", Scheme::fp()),
        ("int8", Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive)),
    ] {
        let mut s = Session::load(&variant)?;
        if let Ok(c) = cushioncache::cushion::load_cushion(&variant, "default") {
            s.set_cushion(c)?;
        }
        if scheme.gran.needs_calibration() {
            calibrate::calibrate_into(&mut s, scheme.act_levels(), 2)?;
        }
        router.add_engine(mode, Scheduler::new(Engine::new(s, scheme)?));
    }
    println!("router modes: {:?}", router.modes());

    let g = Grammar::new(512);
    let mut base = SplitMix64::new(CORPUS_SEED);
    let mut rng = base.fork(STREAM_SERVE + 7);
    let mut sent = Vec::new();
    for i in 0..10u64 {
        let mut r = rng.fork(i);
        let prompt = g.document(32 + r.next_below(32) as usize, &mut r);
        let mode = if i % 3 == 0 { "fp" } else { "int8" };
        let req = Request::new(i + 1, prompt, 8);
        router.route(mode, req)?;
        sent.push((i + 1, mode));
    }
    // fault isolation: an unknown mode fails at routing (per-request)…
    assert!(router.route("w4", Request::new(90, g.document(8, &mut rng), 4)).is_err());
    // …and an oversized prompt errors inside its engine without touching
    // the other mode's traffic
    let seq_len = router
        .scheduler_mut("int8")
        .unwrap()
        .engine
        .session
        .manifest
        .seq_len;
    router.route("int8", Request::new(11, vec![5; seq_len + 1], 4))?;
    sent.push((11, "int8"));

    let mut responses = router.run_to_completion()?;
    responses.sort_by_key(|r| r.id);
    for r in &responses {
        let mode = sent.iter().find(|(id, _)| *id == r.id).unwrap().1;
        println!(
            "req {:2} [{:4}] {} tokens, ttft {:5.1} ms, finish {}",
            r.id, mode, r.tokens.len(), r.ttft.unwrap_or(0.0) * 1e3, r.finished.as_str()
        );
    }
    assert_eq!(responses.len(), 11);
    assert_eq!(responses.iter().filter(|r| r.finished.is_error()).count(), 1);
    assert!(responses[..10].iter().all(|r| !r.finished.is_error()));
    assert_eq!(router.pending_assignments(), 0);
    println!("all requests served; bad ones errored alone; router drained cleanly");
    Ok(())
}
