//! Serving demo: spin up the coordinator with a synthetic client load and
//! report TTFT / TPOT / throughput (the Appendix A.2 measurement, scaled
//! to this testbed).
//!
//!   cargo run --release --example serve_quantized [variant] [gran] [n_reqs]
//!
//! Drives the continuous-batching scheduler directly (in-process) with a
//! Poisson-ish arrival pattern; `cushiond serve` exposes the same engine
//! over TCP.

use cushioncache::coordinator::{Engine, Scheduler};
use cushioncache::data::grammar::{Grammar, CORPUS_SEED, STREAM_SERVE};
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::util::prng::SplitMix64;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tl-llama".into());
    let gran = std::env::args().nth(2).unwrap_or_else(|| "pts".into());
    let n_reqs: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    let gran = match gran.as_str() {
        "fp" => Granularity::Fp,
        "pts" => Granularity::PerTensorStatic,
        "ptd" => Granularity::PerTensorDynamic,
        "ptk" => Granularity::PerTokenDynamic,
        g => anyhow::bail!("bad granularity {g}"),
    };
    let scheme = if gran == Granularity::Fp {
        Scheme::fp()
    } else {
        Scheme::w8a8(gran, Algorithm::Naive)
    };

    let mut session = Session::load(&variant)?;
    if let Ok(c) = cushioncache::cushion::load_cushion(&variant, "default") {
        println!("using stored cushion ({} tokens)", c.len);
        session.set_cushion(c)?;
    }
    if scheme.gran.needs_calibration() {
        calibrate::calibrate_into(&mut session, scheme.act_levels(), 4)?;
    }

    let engine = Engine::new(session, scheme)?;
    let mut sched = Scheduler::new(engine);

    // pre-warm: compile prefill+decode before timing (excluded from TTFT)
    sched.submit(vec![cushioncache::data::BOS, 10, 11], 2);
    sched.run_to_completion()?;
    let _ = sched.take_finished();
    sched.metrics = Default::default();

    // synthetic workload: prompts of 64..96 tokens, 16..32 new tokens
    let g = Grammar::new(sched.engine.session.manifest.vocab);
    let mut base = SplitMix64::new(CORPUS_SEED);
    let mut rng = base.fork(STREAM_SERVE);
    let mut pending: Vec<(usize, Vec<i32>, usize)> = (0..n_reqs)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            let plen = 64 + r.next_below(32) as usize;
            let new = 16 + r.next_below(16) as usize;
            (i, g.document(plen, &mut r), new)
        })
        .collect();
    pending.reverse();

    let t0 = std::time::Instant::now();
    // feed 4 requests up front, then one per step (open-loop-ish arrivals)
    for _ in 0..4 {
        if let Some((_, p, n)) = pending.pop() {
            sched.submit(p, n);
        }
    }
    // hostile traffic mixed in: an oversized prompt and an out-of-vocab
    // token — both must become per-request errors, not engine failures
    let seq_len = sched.engine.session.manifest.seq_len;
    let vocab = sched.engine.session.manifest.vocab as i32;
    sched.submit(vec![5; seq_len + 1], 4);
    sched.submit(vec![cushioncache::data::BOS, vocab + 9], 4);
    while sched.has_work() || !pending.is_empty() {
        if let Some((_, p, n)) = pending.pop() {
            sched.submit(p, n);
        }
        sched.step()?;
    }
    let m = sched.metrics.summary();
    assert_eq!(m.errored, 2, "hostile requests should error per-request");
    assert_eq!(m.completed, n_reqs, "valid requests must all survive");
    println!("\n== serve_quantized: {variant} / {} ==", scheme.label());
    println!("requests          : {} ok, {} errored, {} rejected",
             m.completed, m.errored, m.rejected);
    println!("wall-clock        : {:.2}s", t0.elapsed().as_secs_f64());
    println!("throughput        : {:.1} tok/s", m.tokens_per_second());
    println!("TTFT  mean / p99  : {:.1} / {:.1} ms", m.ttft_mean * 1e3, m.ttft_p99 * 1e3);
    println!("TPOT  mean / p99  : {:.1} / {:.1} ms", m.tpot_mean * 1e3, m.tpot_p99 * 1e3);
    println!(
        "decode step       : mean {:.1} / p50 {:.1} / p99 {:.1} ms at batch {:.1}",
        m.decode_mean * 1e3, m.decode_p50 * 1e3, m.decode_p99 * 1e3, m.mean_batch
    );
    println!("decode histogram  : {}", sched.metrics.decode_histogram_line());
    println!(
        "steady-state xfer : {:.0} B up + {:.0} B down per decode step",
        m.decode_bytes_up_per_step, m.decode_bytes_down_per_step
    );
    println!("prefill mean      : {:.1} ms", m.prefill_mean * 1e3);
    Ok(())
}
